"""Chaos tests (docs/robustness.md): deterministic fault injection,
crash-consistent snapshot/restore (bit-identical tokens across host kills
at every tick), checkpoint-store crash consistency, per-request deadlines
and SLO-aware load shedding, and the fused-kernel circuit breaker."""

import json

import jax
import numpy as np
import pytest

from repro.checkpoint.store import (
    gc_staging,
    latest_step,
    list_prefix_records,
    load_prefix_record,
    load_snapshot,
    save_snapshot,
)
from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.models import transformer as T
from repro.serving import (
    CheckpointInterrupted,
    ContinuousBatchingEngine,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    PagedEngine,
    Request,
    ServeConfig,
    serve_with_chaos,
)


@pytest.fixture(scope="module")
def model():
    cfg = reduced_config("stablelm-1.6b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, lens, max_new=4, seed=0, prefix_len=0, repetitive=False):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab, prefix_len, dtype=np.int32)
    out = []
    for L in lens:
        if repetitive:
            # Period-3 token loop: gives the n-gram drafter real matches.
            tail = np.tile(rng.integers(0, cfg.vocab, 3, dtype=np.int32),
                           (L + 2) // 3)[:L]
        else:
            tail = rng.integers(0, cfg.vocab, L, dtype=np.int32)
        out.append(Request(prompt=np.concatenate([prefix, tail]),
                           max_new_tokens=max_new))
    return out


def _copies(reqs):
    return [Request(prompt=r.prompt.copy(), max_new_tokens=r.max_new_tokens,
                    deadline_ticks=r.deadline_ticks, slo=r.slo)
            for r in reqs]


def _tokens(reqs):
    return [r.generated for r in reqs]


# ---------------------------------------------------------------------------
# plan / injector unit semantics (no model)
# ---------------------------------------------------------------------------


def test_fault_plan_construction_and_roundtrip():
    plan = FaultPlan.scripted([("crash", 3), FaultEvent("pool_dry", 0)])
    assert plan.events == (FaultEvent("crash", 3), FaultEvent("pool_dry", 0))
    assert FaultPlan.from_json(plan.to_json()) == plan
    # Seed-derived plans are pure functions of the seed.
    assert (FaultPlan.from_seed(7, 5, 20) == FaultPlan.from_seed(7, 5, 20))
    assert all(e.kind and 0 <= e.tick <= 20
               for e in FaultPlan.from_seed(7, 5, 20).events)
    with pytest.raises(ValueError):
        FaultEvent("meteor_strike", 1)
    with pytest.raises(ValueError):
        FaultEvent("crash", -1)


def test_fault_injector_armed_fire_semantics():
    inj = FaultInjector(FaultPlan.scripted(
        [("crash", 3), ("crash", 5), ("kernel_fail", 0)]))
    # Not armed yet.
    assert not inj.fire("crash", 2)
    # kernel_fail armed from tick 0, fires once and is consumed.
    assert inj.fire("kernel_fail", 2)
    assert not inj.fire("kernel_fail", 99)
    # First consultation at-or-after the tick fires the earliest event.
    assert inj.fire("crash", 4)            # consumes the tick-3 event
    assert not inj.fire("crash", 4)        # tick-5 event not armed yet
    assert inj.fire("crash", 7)
    rep = inj.report()
    assert rep["fired"] == [("kernel_fail", 0, 2), ("crash", 3, 4),
                            ("crash", 5, 7)]
    assert rep["fired_by_kind"] == {"kernel_fail": 1, "crash": 2}
    assert rep["unfired"] == []


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


def test_serve_config_robustness_knob_validation():
    ok = dict(max_len=32, max_slots=2, prefill_bucket=8)
    with pytest.raises(ValueError, match="deadline_ticks"):
        ServeConfig(**ok, deadline_ticks=0)
    with pytest.raises(ValueError, match="shed_watermark"):
        ServeConfig(**ok, oversubscribe=True, shed_watermark=1.0)
    with pytest.raises(ValueError, match="shed_watermark"):
        ServeConfig(**ok, oversubscribe=True, shed_watermark=0.0)
    # Shedding without oversubscription can never relieve anything:
    # worst-case-reserved admission blocks the head of line instead.
    with pytest.raises(ValueError, match="oversubscribe"):
        ServeConfig(**ok, shed_watermark=0.5)
    with pytest.raises(ValueError, match="snapshot_every"):
        ServeConfig(**ok, snapshot_every=-1)
    ServeConfig(**ok, oversubscribe=True, shed_watermark=0.5,
                deadline_ticks=4, snapshot_every=2)  # valid combination


def test_continuous_engine_rejects_robustness_knobs(model):
    cfg, params = model
    scfg = ServeConfig(max_len=32, max_slots=2, prefill_bucket=8,
                       deadline_ticks=4)
    with pytest.raises(ValueError, match="PagedEngine"):
        ContinuousBatchingEngine(cfg, params, scfg)


# ---------------------------------------------------------------------------
# checkpoint store: torn snapshots are never exposed
# ---------------------------------------------------------------------------


def test_snapshot_store_crash_consistency(tmp_path):
    d = str(tmp_path)
    save_snapshot({"v": 1}, d, step=1)
    assert load_snapshot(d) == ({"v": 1}, 1)

    def die():
        raise CheckpointInterrupted("killed between stage and promote")

    with pytest.raises(CheckpointInterrupted):
        save_snapshot({"v": 2}, d, step=2, interrupt=die)
    # The torn write is invisible: latest promoted state still serves.
    assert latest_step(d) == 1
    assert load_snapshot(d) == ({"v": 1}, 1)
    # ... but its staging orphan exists on disk until GC'd.
    orphans = [n for n in tmp_path.iterdir() if ".tmp" in n.name]
    assert len(orphans) == 1
    assert gc_staging(d, grace=3600.0) == []       # too young for aged GC
    assert len(gc_staging(d, grace=0.0)) == 1      # single-writer reclaim
    assert [n for n in tmp_path.iterdir() if ".tmp" in n.name] == []
    # A later clean save supersedes normally.
    save_snapshot({"v": 3}, d, step=3)
    assert load_snapshot(d) == ({"v": 3}, 3)


def test_engine_snapshot_is_json_and_restore_guarded(model, tmp_path):
    cfg, params = model
    scfg = ServeConfig(max_len=32, max_slots=2, prefill_bucket=8,
                       page_size=8)
    eng = PagedEngine(cfg, params, scfg)
    reqs = _reqs(cfg, (6, 9), max_new=3)
    eng.generate(reqs, seed=0)
    state = eng.snapshot()
    json.dumps(state)                               # fully serializable
    assert state["version"] == 1 and state["ticks"] == eng.ticks
    assert [r["generated"] for r in state["requests"]] == _tokens(reqs)
    # restore() refuses a used engine ...
    with pytest.raises(RuntimeError, match="freshly constructed"):
        eng.restore(state)
    # ... and an unknown snapshot version.
    fresh = PagedEngine(cfg, params, scfg)
    with pytest.raises(ValueError, match="version"):
        fresh.restore({**state, "version": 99})


# ---------------------------------------------------------------------------
# the tentpole property: kill + restore at EVERY tick is invisible
# ---------------------------------------------------------------------------


def test_crash_restore_bit_identical_at_every_tick(model, tmp_path):
    """Property sweep: snapshot every tick, kill the host at tick k for
    every k in the trace, restore, and require the served tokens to be
    bit-identical to an undisturbed run — no matter where the kill
    lands (mid-queue, mid-chunked-prefill, mid-decode, at the end)."""
    cfg, params = model
    scfg = ServeConfig(max_len=48, max_slots=2, prefill_bucket=8,
                       page_size=8, prefill_chunk=8, snapshot_every=1)
    trace = _reqs(cfg, (6, 17), max_new=3)   # 17 > chunk: multi-tick prefill

    ref = _copies(trace)
    ref_eng = PagedEngine(cfg, params, scfg)
    ref_eng.generate(ref, seed=0)
    n_ticks = ref_eng.ticks
    assert n_ticks >= 4

    for k in range(n_ticks):
        out, rep = serve_with_chaos(
            lambda: PagedEngine(cfg, params, scfg), _copies(trace),
            seed=0, plan=FaultPlan.scripted([("crash", k)]),
            snapshot_dir=str(tmp_path / f"k{k}"))
        assert rep["crashes"] == 1 and rep["restores"] == 1, k
        assert _tokens(out) == _tokens(ref), \
            f"kill at tick {k} changed the served tokens"


def test_crash_without_snapshot_dir_is_fatal(model):
    cfg, params = model
    scfg = ServeConfig(max_len=32, max_slots=2, prefill_bucket=8,
                       page_size=8)
    with pytest.raises(RuntimeError, match="died at tick"):
        serve_with_chaos(lambda: PagedEngine(cfg, params, scfg),
                         _reqs(cfg, (6,), max_new=3), seed=0,
                         plan=FaultPlan.scripted([("crash", 1)]))


def test_chaos_storm_speculative_oversubscribed(model, tmp_path):
    """Kill-mid-speculative-tick plus a drafter failure, a forced
    pool-dry preemption and an interrupted snapshot write, on an
    oversubscribed pool — tokens bit-identical to the undisturbed run."""
    cfg, params = model
    scfg = ServeConfig(max_len=64, max_slots=2, prefill_bucket=8,
                       page_size=8, pool_blocks=12, oversubscribe=True,
                       speculative="ngram", draft_k=3, snapshot_every=2)
    trace = _reqs(cfg, (9, 12), max_new=14, repetitive=True)

    ref = _copies(trace)
    PagedEngine(cfg, params, scfg).generate(ref, seed=0)

    plan = FaultPlan.scripted([("crash", 2), ("drafter_fail", 2),
                               ("pool_dry", 3), ("checkpoint_interrupt", 4),
                               ("crash", 4)])
    out, rep = serve_with_chaos(
        lambda: PagedEngine(cfg, params, scfg), _copies(trace),
        seed=0, plan=plan, snapshot_dir=str(tmp_path))
    assert _tokens(out) == _tokens(ref)
    assert rep["crashes"] == 2 and rep["restores"] == 2
    # The tick-4 snapshot write was interrupted, so the second crash falls
    # back to the older tick-2 snapshot — more replay, same tokens.
    assert rep["snapshots_interrupted"] == 1
    assert rep["staging_reclaimed"] == 1
    assert rep["engine_counters"]["drafter_failures"] >= 1
    assert rep["fired_by_kind"]["crash"] == 2
    assert rep["fired_by_kind"]["pool_dry"] == 1
    assert rep["unfired"] == []


def test_broken_drafter_degrades_to_plain_decode(model):
    """A drafter that raises at propose time (a real exception, not an
    injected fault) must not kill the tick — proposals are dropped, the
    tick decodes plainly, and the tokens match a no-speculation serve."""
    cfg, params = model

    class ExplodingDrafter:
        def propose(self, *a, **kw):
            raise RuntimeError("drafter model segfaulted")

        def observe(self, *a, **kw):
            pass

    base = dict(max_len=32, max_slots=2, prefill_bucket=8, page_size=8)
    trace = _reqs(cfg, (6, 9), max_new=4)
    ref = _copies(trace)
    PagedEngine(cfg, params, ServeConfig(**base)).generate(ref, seed=0)

    eng = PagedEngine(cfg, params,
                      ServeConfig(**base, speculative="ngram"),
                      drafter=ExplodingDrafter())
    reqs = _copies(trace)
    eng.generate(reqs, seed=0)
    assert _tokens(reqs) == _tokens(ref)
    assert eng.counters["drafter_failures"] >= 1
    assert eng.counters["spec_accepted"] == 0


def test_kernel_circuit_breaker_bitstopper_fused(model, tmp_path):
    """BitStopper fused decode under a kernel fault + host crash: the
    circuit breaker degrades to the gather fallback mid-trace, a crash
    later kills the degraded engine, and the restored run (fused again,
    degraded again by nothing — the fault was consumed) still serves
    bit-identical tokens.  Pins the amax-restore argument: the restored
    quant scales must reproduce the crash-time quantization grid."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.8))
    scfg = ServeConfig(max_len=48, max_slots=2, prefill_bucket=8,
                       page_size=8, fused_decode=True, snapshot_every=1,
                       prefix_sharing=True)
    trace = _reqs(cfg, (6, 9), max_new=6, prefix_len=8)

    ref = _copies(trace)
    PagedEngine(cfgb, params, scfg).generate(ref, seed=0)

    out, rep = serve_with_chaos(
        lambda: PagedEngine(cfgb, params, scfg), _copies(trace),
        seed=0, plan=FaultPlan.scripted([("kernel_fail", 2), ("crash", 4)]),
        snapshot_dir=str(tmp_path))
    assert _tokens(out) == _tokens(ref)
    assert rep["engine_counters"]["degradations"] == 1
    assert rep["crashes"] == 1
    assert rep["fired_by_kind"] == {"kernel_fail": 1, "crash": 1}


def test_kernel_fault_not_consulted_on_fallback_path(model):
    """With the gather fallback configured there is no fused kernel to
    fail: the injected kernel_fail must stay unfired, not crash the
    fallback."""
    cfg, params = model
    scfg = ServeConfig(max_len=32, max_slots=2, prefill_bucket=8,
                       page_size=8, fused_decode=False)
    out, rep = serve_with_chaos(
        lambda: PagedEngine(cfg, params, scfg),
        _reqs(cfg, (6,), max_new=3), seed=0,
        plan=FaultPlan.scripted([("kernel_fail", 0)]))
    assert len(out[0].generated) == 3
    assert rep["unfired"] == [("kernel_fail", 0)]
    assert rep["engine_counters"]["degradations"] == 0


# ---------------------------------------------------------------------------
# deadlines, SLO classes, load shedding
# ---------------------------------------------------------------------------


def test_deadline_truncates_to_prefix(model):
    cfg, params = model
    base = dict(max_len=48, max_slots=2, prefill_bucket=8, page_size=8)
    ref = _reqs(cfg, (9,), max_new=10)
    PagedEngine(cfg, params, ServeConfig(**base)).generate(ref, seed=0)
    assert len(ref[0].generated) == 10

    ddl = _reqs(cfg, (9,), max_new=10)
    ddl[0].deadline_ticks = 6
    eng = PagedEngine(cfg, params, ServeConfig(**base))
    eng.generate(ddl, seed=0)
    # Truncated, never mutated: emitted tokens are a prefix.
    assert 0 < len(ddl[0].generated) < 10
    assert ddl[0].generated == ref[0].generated[:len(ddl[0].generated)]
    assert ddl[0].deadline_hit and ddl[0].shed_reason is None
    assert eng.counters["deadline_truncated"] == 1
    assert eng.counters["requests_finished"] == 1


def test_deadline_expiry_in_queue_sheds(model):
    """Requests that expire before ever starting are shed (not truncated):
    one slot serializes the queue, so the tail's deadlines lapse while
    the head decodes."""
    cfg, params = model
    eng = PagedEngine(cfg, params,
                      ServeConfig(max_len=32, max_slots=1,
                                  prefill_bucket=8, page_size=8))
    reqs = _reqs(cfg, (9, 9, 9), max_new=8)
    for r in reqs:
        r.deadline_ticks = 2
    eng.generate(reqs, seed=0)
    assert reqs[0].generated and reqs[0].deadline_hit
    for r in reqs[1:]:
        assert r.shed_reason == "deadline" and not r.generated
    assert eng.counters["shed_deadline"] == 2
    assert eng.counters["requests_shed"] == 2


def test_watermark_shedding_exact_and_besteffort_only(model):
    cfg, params = model
    scfg = ServeConfig(max_len=64, max_slots=4, prefill_bucket=8,
                       page_size=8, pool_blocks=6, oversubscribe=True,
                       shed_watermark=0.5)
    trace = _reqs(cfg, (9, 9, 9, 9), max_new=8)
    for r in trace[1:]:
        r.slo = "besteffort"

    # Reference without QoS: same trace, everyone finishes.
    ref = _copies(trace)
    PagedEngine(cfg, params,
                ServeConfig(max_len=64, max_slots=4, prefill_bucket=8,
                            page_size=8, pool_blocks=6,
                            oversubscribe=True)).generate(ref, seed=0)

    def shed_run():
        reqs = _copies(trace)
        eng = PagedEngine(cfg, params, scfg)
        eng.generate(reqs, seed=0)
        return reqs, eng

    reqs, eng = shed_run()
    shed = [r for r in reqs if r.shed_reason]
    assert shed and eng.counters["shed_watermark"] == len(shed)
    for r in shed:
        assert r.slo == "besteffort" and r.shed_reason == "watermark"
        assert not r.generated
    # The standard head is never shed, and survivors' tokens are exactly
    # the reference streams (schedule-invariant sampling).
    assert reqs[0].shed_reason is None
    for r, rr in zip(reqs, ref):
        if r.shed_reason is None:
            assert r.generated == rr.generated
    # Shedding is a pure function of the trace: the exact rejection set
    # reproduces run over run.
    reqs2, _ = shed_run()
    assert ([(r.rid, r.shed_reason) for r in reqs2]
            == [(r.rid, r.shed_reason) for r in reqs])


def test_forced_pool_dry_preemption_is_lossless(model):
    """An injected pool_dry forces a preemption cycle on an unreserved
    block claim even though the pool has spare capacity — exercising the
    lossless preempt/resume machinery at a scripted point."""
    cfg, params = model
    scfg = ServeConfig(max_len=48, max_slots=2, prefill_bucket=8,
                       page_size=8, pool_blocks=16, oversubscribe=True)
    # Generations must outrun the oversubscribed reservation (prompt
    # blocks + 1 decode block) so an *unreserved* claim actually occurs:
    # 9 prompt + 18 new spans 4 blocks against a 3-block reservation.
    trace = _reqs(cfg, (9, 9), max_new=18)
    ref = _copies(trace)
    PagedEngine(cfg, params,
                ServeConfig(max_len=48, max_slots=2, prefill_bucket=8,
                            page_size=8)).generate(ref, seed=0)

    out, rep = serve_with_chaos(
        lambda: PagedEngine(cfg, params, scfg), _copies(trace),
        seed=0, plan=FaultPlan.scripted([("pool_dry", 4)]))
    assert _tokens(out) == _tokens(ref)
    assert rep["fired_by_kind"] == {"pool_dry": 1}
    assert rep["engine_counters"]["forced_preemptions"] == 1
    assert rep["engine_counters"]["preemptions"] >= 1


def test_slo_aware_victim_selection(model):
    """Under pool pressure a besteffort slot is preempted before any
    other, even when the base fewest-tokens policy would prefer a
    different victim — SLO class outranks recompute cost."""
    cfg, params = model
    scfg = ServeConfig(max_len=64, max_slots=3, prefill_bucket=8,
                       page_size=8, pool_blocks=10, oversubscribe=True,
                       preempt_policy="fewest_tokens")
    # Three co-resident requests, staggered by prefill order, so when the
    # head request needs its (unreserved) 4th block there are TWO victim
    # candidates: the besteffort one has generated MORE than the standard
    # one, so fewest_tokens alone would pick the standard request.
    reqs = _reqs(cfg, (9, 9, 9), max_new=20)
    reqs[0].slo = "strict"
    reqs[1].slo = "besteffort"
    reqs[2].slo = "standard"
    eng = PagedEngine(cfg, params, scfg)
    eng.generate(reqs, seed=0)
    assert eng.counters["preemptions"] >= 1
    # Only the besteffort request was ever victimized.
    assert reqs[0].preemptions == 0
    assert reqs[2].preemptions == 0
    assert reqs[1].preemptions >= 1
    # Losslessness still holds for all three.
    ref = _copies(reqs)
    PagedEngine(cfg, params,
                ServeConfig(max_len=64, max_slots=3, prefill_bucket=8,
                            page_size=8)).generate(ref, seed=0)
    assert _tokens(reqs) == _tokens(ref)


def test_invalid_request_qos_rejected(model):
    cfg, params = model
    eng = PagedEngine(cfg, params,
                      ServeConfig(max_len=32, max_slots=2,
                                  prefill_bucket=8, page_size=8))
    with pytest.raises(ValueError, match="slo"):
        eng.submit(Request(prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=2, slo="platinum"))
    with pytest.raises(ValueError, match="deadline_ticks"):
        eng.submit(Request(prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=2, deadline_ticks=0))


# ---------------------------------------------------------------------------
# KV memory hierarchy under chaos (docs/serving.md "Memory hierarchy")
# ---------------------------------------------------------------------------

# Oversubscribed pool shape from the swap sweep (test_swap.py): decode
# outgrows the prompt-sized reservations, so a mid-decode claim preempts
# a victim whose blocks the swap tier captures.
_HIER = dict(max_len=64, max_slots=3, prefill_bucket=8, page_size=8,
             pool_blocks=10, oversubscribe=True)


def test_swap_fail_falls_back_to_recompute(model):
    """A host-copy failure mid-swap-out must degrade the preemption to
    the recompute path, not corrupt it: the victim resumes via chunked
    prefill and the trace stays bit-identical to no-swap serving."""
    cfg, params = model
    trace = _reqs(cfg, (12, 9, 11), max_new=16)
    ref = _copies(trace)
    PagedEngine(cfg, params, ServeConfig(**_HIER)).generate(ref, seed=0)

    scfg = ServeConfig(**_HIER, swap_host_bytes=1 << 22)
    out, rep = serve_with_chaos(
        lambda: PagedEngine(cfg, params, scfg), _copies(trace),
        seed=0, plan=FaultPlan.scripted([("swap_fail", 0)]))
    assert _tokens(out) == _tokens(ref)
    assert rep["fired_by_kind"] == {"swap_fail": 1}
    assert rep["engine_counters"]["swap_fallbacks"] >= 1
    assert rep["engine_counters"]["swap_ins"] == 0


def test_prefix_spill_interrupt_torn_write_invisible(model, tmp_path):
    """An interrupted prefix-store spill must never publish a torn
    record: the staging orphan is invisible to readers, single-writer GC
    reclaims it, every promoted record still round-trips, and a restarted
    engine warmed from the store serves bit-identical tokens."""
    cfg, params = model
    d = str(tmp_path)
    base = dict(max_len=64, max_slots=2, prefill_bucket=8, page_size=8,
                prefill_chunk=8)
    # Pool snug enough that later admissions LRU-steal parked registered
    # blocks, spilling them to disk (same shape as test_swap.py).
    eng = PagedEngine(cfg, params, ServeConfig(
        **base, pool_blocks=8, prefix_store_dir=d))
    eng.chaos = FaultInjector(
        FaultPlan.scripted([("checkpoint_interrupt", 0)]))
    eng.generate(_reqs(cfg, (9, 11, 10, 9, 11), max_new=16, seed=8,
                       prefix_len=16), seed=0)
    assert eng.counters["prefix_spills"] >= 2
    assert eng.counters["prefix_store_interrupts"] == 1

    # The torn write left a staging orphan but no readable record ...
    orphans = [n for n in tmp_path.iterdir() if ".tmp" in n.name]
    assert len(orphans) == 1
    chains = list_prefix_records(d)
    assert len(chains) >= 1
    for chain in chains:                 # every promoted record is whole
        assert load_prefix_record(d, chain) is not None
    # ... and the single-writer reclaim sweeps it.
    assert len(gc_staging(d, grace=0.0)) == 1
    assert [n for n in tmp_path.iterdir() if ".tmp" in n.name] == []

    # Graceful shutdown persists the still-parked registry (the hot
    # system-prefix blocks were never LRU-stolen, so only the flush
    # writes them); then a restarted engine warms losslessly.
    eng.flush_prefixes()
    cold_eng = PagedEngine(cfg, params, ServeConfig(**base))
    cold = _reqs(cfg, (9, 11), max_new=8, seed=8, prefix_len=16)
    cold_eng.generate(cold, seed=0)
    warm_eng = PagedEngine(cfg, params, ServeConfig(
        **base, prefix_store_dir=d))
    warm = _reqs(cfg, (9, 11), max_new=8, seed=8, prefix_len=16)
    warm_eng.generate(warm, seed=0)
    assert warm_eng.counters["prefix_store_hits"] >= 1
    assert _tokens(warm) == _tokens(cold)


def test_crash_restore_every_tick_swapping_trace(model, tmp_path):
    """Kill + restore at EVERY tick of a trace that swaps: host swap
    records die with the host (the JSON snapshot never carries KV), so a
    restored victim resumes via recompute — and no matter where the kill
    lands (before swap-out, while the record is live, after swap-in) the
    served tokens never move."""
    cfg, params = model
    scfg = ServeConfig(**_HIER, swap_host_bytes=1 << 22, snapshot_every=1)
    trace = _reqs(cfg, (12, 9, 11), max_new=16)

    ref = _copies(trace)
    ref_eng = PagedEngine(cfg, params, scfg)
    ref_eng.generate(ref, seed=0)
    assert ref_eng.counters["swap_outs"] >= 1
    assert ref_eng.counters["swap_ins"] >= 1
    n_ticks = ref_eng.ticks

    for k in range(n_ticks):
        out, rep = serve_with_chaos(
            lambda: PagedEngine(cfg, params, scfg), _copies(trace),
            seed=0, plan=FaultPlan.scripted([("crash", k)]),
            snapshot_dir=str(tmp_path / f"k{k}"))
        assert rep["crashes"] == 1 and rep["restores"] == 1, k
        assert _tokens(out) == _tokens(ref), \
            f"kill at tick {k} changed the served tokens"


def test_cross_restart_prefix_warm_start_zero_prefill(model, tmp_path):
    """Cross-restart warm start: an engine flushes its prefix registry
    on shutdown; a NEW engine process pointed at the same store serves a
    resumed, fully block-aligned request with ZERO prefill chunks, and
    its continuation matches recompute bit for bit."""
    cfg, params = model
    d = str(tmp_path)
    base = dict(max_len=64, max_slots=2, prefill_bucket=8, page_size=8,
                prefill_chunk=8)
    sys_prompt = np.random.default_rng(42).integers(
        0, cfg.vocab, 16, dtype=np.int32)

    def tails(seed=3):
        rng = np.random.default_rng(seed)
        return [Request(prompt=np.concatenate(
                            [sys_prompt,
                             rng.integers(0, cfg.vocab, L, dtype=np.int32)]),
                        max_new_tokens=8)
                for L in (6, 9)]

    first = PagedEngine(cfg, params, ServeConfig(**base,
                                                 prefix_store_dir=d))
    first.generate(tails(), seed=0)
    assert first.flush_prefixes() >= 2       # 16-token prefix = 2 blocks
    del first                                # "host restart"

    def resumed():
        r = Request(prompt=sys_prompt[:15].copy(), max_new_tokens=4)
        # resume ctx = prompt + generated[:-1] = 16 tokens = 2 stored
        # blocks, so re-materialization needs no prefill at all
        r.generated = [int(sys_prompt[15]), 42]
        return r

    ref_eng = PagedEngine(cfg, params, ServeConfig(**base))
    ref = resumed()
    ref_eng.generate([ref], seed=0)
    assert ref_eng.counters["prefill_chunks"] > 0

    warm_eng = PagedEngine(cfg, params, ServeConfig(**base,
                                                    prefix_store_dir=d))
    got = resumed()
    warm_eng.generate([got], seed=0)
    assert warm_eng.counters["prefill_chunks"] == 0
    assert warm_eng.counters["prefix_store_hits"] >= 1
    assert got.generated == ref.generated


def test_chaos_with_deadlines_is_deterministic(model, tmp_path):
    """Crash recovery consumes ticks, so deadlines interact with faults —
    the combination is still a pure function of (trace, plan): two
    identical chaos runs produce identical tokens, identical shed sets
    and identical truncations."""
    cfg, params = model
    scfg = ServeConfig(max_len=48, max_slots=2, prefill_bucket=8,
                       page_size=8, pool_blocks=8, oversubscribe=True,
                       deadline_ticks=9, shed_watermark=0.6,
                       snapshot_every=1)
    trace = _reqs(cfg, (9, 9, 9), max_new=6)
    for r in trace[1:]:
        r.slo = "besteffort"
    plan = FaultPlan.scripted([("crash", 4)])

    def run(sub):
        return serve_with_chaos(
            lambda: PagedEngine(cfg, params, scfg), _copies(trace),
            seed=0, plan=plan, snapshot_dir=str(tmp_path / sub))

    out1, rep1 = run("a")
    out2, rep2 = run("b")
    assert _tokens(out1) == _tokens(out2)
    assert ([(r.rid, r.shed_reason, r.deadline_hit) for r in out1]
            == [(r.rid, r.shed_reason, r.deadline_hit) for r in out2])
    assert rep1["fired"] == rep2["fired"]
    assert rep1["engine_counters"] == rep2["engine_counters"]
