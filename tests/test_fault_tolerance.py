"""Runtime fault-tolerance tests: heartbeat cluster monitor, elastic mesh
decisions, straggler EMA policy, and the stuck-tick engine watchdog — all
driven by injected fake clocks (never real ``time.monotonic``), matching
the wall-clock discipline in docs/robustness.md."""

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import transformer as T
from repro.runtime import (
    ClusterMonitor,
    ElasticMeshManager,
    EngineWatchdog,
    StragglerPolicy,
    StuckTickError,
)
from repro.serving import PagedEngine, Request, ServeConfig


class FakeClock:
    """Deterministic monotonic clock: advances only when told to."""

    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        self.now += dt


# ---------------------------------------------------------------------------
# ClusterMonitor / ElasticMeshManager / StragglerPolicy
# ---------------------------------------------------------------------------


def test_cluster_monitor_timeouts_on_injected_clock():
    clk = FakeClock()
    mon = ClusterMonitor(n_nodes=4, timeout=10.0, clock=clk)
    assert mon.failed_nodes() == set() and mon.healthy_count() == 4

    clk.advance(9.0)
    for n in (0, 1, 2):                    # node 3 goes silent
        mon.heartbeat(n)
    assert mon.failed_nodes() == set()     # 9s silence < 10s timeout
    clk.advance(2.0)
    assert mon.failed_nodes() == {3}
    assert mon.healthy_count() == 3

    mon.inject_failure(1)                  # failure beats heartbeats
    mon.heartbeat(1)
    assert mon.failed_nodes() == {1, 3}
    mon.recover(1)
    assert mon.failed_nodes() == {3}


def test_elastic_mesh_preserves_tp_and_shrinks_data():
    mgr = ElasticMeshManager(model_parallel=4, devices_per_node=4)
    d = mgr.decide(healthy_nodes=3)        # 12 devices / tp=4
    assert (d.data, d.model, d.devices) == (3, 4, 12)
    # Non-divisible survivor counts round the data axis down.
    assert mgr.decide(healthy_nodes=5).data == 5
    mgr2 = ElasticMeshManager(model_parallel=8, devices_per_node=4)
    assert mgr2.decide(healthy_nodes=3).data == 1
    with pytest.raises(RuntimeError, match="cannot host"):
        mgr2.decide(healthy_nodes=1)


def test_straggler_policy_ema_and_reassignment():
    pol = StragglerPolicy(slack=2.0, ema_alpha=0.5)
    assert pol.deadline() is None
    assert not pol.is_straggler(1e9)       # no EMA yet: nothing to compare
    pol.observe(1.0)
    assert pol.deadline() == pytest.approx(2.0)
    assert not pol.is_straggler(2.0) and pol.is_straggler(2.1)
    pol.observe(2.0)                       # ema -> 1.5, deadline -> 3.0
    assert pol.deadline() == pytest.approx(3.0)
    # Donor choice is a pure function of (step, failed shard).
    healthy = [0, 1, 2, 5]
    picks = [StragglerPolicy.reassign_shard(3, healthy, s) for s in range(4)]
    assert picks == [StragglerPolicy.reassign_shard(3, healthy, s)
                     for s in range(4)]
    assert all(p in healthy for p in picks)


# ---------------------------------------------------------------------------
# EngineWatchdog
# ---------------------------------------------------------------------------


class TickEngine:
    """Stub engine whose per-tick durations come from a script; the fake
    clock advances by the scripted amount inside step()."""

    def __init__(self, clk, durations):
        self.clk = clk
        self.durations = list(durations)
        self.stepped = 0

    def begin(self, seed=0):
        pass

    def pending(self):
        return bool(self.durations)

    def step(self):
        self.clk.advance(self.durations.pop(0))
        self.stepped += 1
        return self.pending()


def test_watchdog_warmup_never_flags():
    clk = FakeClock()
    # 3 monster compile ticks, then steady state: with warmup=3 the
    # compiles seed the EMA but are exempt from the deadline check.
    eng = TickEngine(clk, [50.0, 40.0, 30.0, 1.0, 1.0, 1.0])
    dog = EngineWatchdog(eng, StragglerPolicy(slack=2.0, ema_alpha=0.5),
                         clock=clk, warmup=3)
    dog.run(seed=0)
    assert eng.stepped == 6
    assert dog.ticks_seen == 6 and dog.last_tick_time == 1.0


def test_watchdog_raises_on_stuck_tick_before_ema_dilution():
    clk = FakeClock()
    eng = TickEngine(clk, [1.0, 1.0, 1.0, 1.0, 100.0, 1.0])
    pol = StragglerPolicy(slack=2.5, ema_alpha=0.1)
    dog = EngineWatchdog(eng, pol, clock=clk, warmup=2)
    with pytest.raises(StuckTickError, match="deadline"):
        dog.run(seed=0)
    assert eng.stepped == 5                # died on the monster tick
    # The monster tick was checked BEFORE joining the EMA: the deadline
    # that caught it is still the steady-state one.
    assert pol.ema == pytest.approx(1.0)
    assert dog.last_tick_time == 100.0


def test_watchdog_rejects_bad_warmup():
    with pytest.raises(ValueError, match="warmup"):
        EngineWatchdog(TickEngine(FakeClock(), []), warmup=0)


def test_watchdog_drains_real_engine_losslessly():
    """The watchdog is a transparent wrapper: draining a real PagedEngine
    under supervision (fake clock, generous slack) serves exactly the
    tokens of an unsupervised run."""
    cfg = reduced_config("stablelm-1.6b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_len=32, max_slots=2, prefill_bucket=8,
                       page_size=8)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, L, dtype=np.int32)
               for L in (6, 9)]

    ref = [Request(prompt=p.copy(), max_new_tokens=3) for p in prompts]
    PagedEngine(cfg, params, scfg).generate(ref, seed=0)

    clk = FakeClock()
    eng = PagedEngine(cfg, params, scfg)
    real_step = eng.step

    def step():
        clk.advance(1.0)       # constant tick time: EMA never trips
        return real_step()

    eng.step = step
    reqs = [Request(prompt=p.copy(), max_new_tokens=3) for p in prompts]
    for r in reqs:
        eng.submit(r)
    dog = EngineWatchdog(eng, StragglerPolicy(slack=2.5), clock=clk,
                         warmup=2)
    dog.run(seed=0)
    assert [r.generated for r in reqs] == [r.generated for r in ref]
    assert dog.ticks_seen == eng.ticks
