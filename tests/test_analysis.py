"""Static-analysis subsystem: seeded violations per kernel rule class,
lint rule negatives, and the CLI's JSON report.

The kernel negatives build tiny in-test ``pl.pallas_call`` invocations
under the recorder (the kernel body never runs) and assert each
deliberately-broken spec is reported with *this* file and the call line
— a checker that can't localize is a checker nobody acts on.
"""

import json
import pathlib
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import kernel_contracts as kc
from repro.analysis.lint import ConfigSpec, run_lint
from repro.analysis.report import KERNEL_RULES, Finding, summarize

THIS = pathlib.Path(__file__).name


def _record_one(fn):
    with kc.record_pallas_calls() as recs:
        fn()
    assert len(recs) == 1
    return recs[0]


def _rules(findings):
    return {f.rule for f in findings}


def _here(findings, rule):
    f = next(f for f in findings if f.rule == rule)
    assert f.file.endswith(THIS), f.file
    assert f.line > 0
    return f


def _noop_kernel(*refs):
    pass


# ---------------------------------------------------------------------------
# recorder + positive control
# ---------------------------------------------------------------------------


def test_recorder_returns_zeros_without_running_kernel():
    def boom(*refs):
        raise RuntimeError("kernel body must not execute")

    with kc.record_pallas_calls() as recs:
        out = pl.pallas_call(
            boom, grid=(2,),
            in_specs=[pl.BlockSpec((4, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((4, 8), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
        )(jnp.ones((8, 8), jnp.float32))
    assert out.shape == (8, 8) and not out.any()
    assert recs[0].grid == (2,)
    assert kc.check_record(recs[0]) == []


def test_seeded_index_map_out_of_bounds():
    def run():
        pl.pallas_call(
            _noop_kernel, grid=(4,),
            in_specs=[pl.BlockSpec((4, 8), lambda i: (i, 0))],  # 2 blocks
            out_specs=pl.BlockSpec((4, 8), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((4, 8), jnp.float32),
        )(jnp.zeros((8, 8), jnp.float32))

    findings = kc.check_record(_record_one(run))
    f = _here(findings, "kernel-index-map-bounds")
    assert "grid point (2,)" in f.message


def test_seeded_output_coverage_gap():
    def run():
        pl.pallas_call(
            _noop_kernel, grid=(2,),
            in_specs=[pl.BlockSpec((8, 8), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((4, 8), lambda i: (0, 0)),  # never (1, 0)
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
        )(jnp.zeros((8, 8), jnp.float32))

    findings = kc.check_record(_record_one(run))
    f = _here(findings, "kernel-output-coverage")
    assert "never written" in f.message


def test_seeded_block_non_divisor():
    def run():
        pl.pallas_call(
            _noop_kernel, grid=(2,),
            in_specs=[pl.BlockSpec((3, 8), lambda i: (0, 0))],  # 3 ∤ 8
            out_specs=pl.BlockSpec((4, 8), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
        )(jnp.zeros((8, 8), jnp.float32))

    _here(kc.check_record(_record_one(run)), "kernel-block-divisor")


def test_seeded_tile_multiple_violation():
    def run():
        pl.pallas_call(
            _noop_kernel, grid=(4,),
            in_specs=[pl.BlockSpec((8, 64), lambda i: (0, i))],
            out_specs=pl.BlockSpec((8, 256), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 256), jnp.float32),
        )(jnp.zeros((8, 256), jnp.float32))

    rec = _record_one(run)
    # 64 divides 256, so only the tile rule fires — and only when asked.
    assert "kernel-tile-multiple" not in _rules(kc.check_record(rec))
    findings = kc.check_record(rec, tile_check=True)
    f = _here(findings, "kernel-tile-multiple")
    assert "128" in f.message


def test_seeded_float_scalar_prefetch():
    def run():
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=(1,),
            in_specs=[pl.BlockSpec((4,), lambda i, s: (0,))],
            out_specs=pl.BlockSpec((4,), lambda i, s: (0,)),
        )
        pl.pallas_call(
            _noop_kernel, grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
        )(jnp.zeros((2,), jnp.float32),      # scalar operand: not integer
          jnp.zeros((4,), jnp.float32))

    findings = kc.check_record(_record_one(run))
    f = _here(findings, "kernel-scalar-prefetch")
    assert "integer" in f.message


def test_seeded_interpret_mismatch():
    def run():
        pl.pallas_call(
            _noop_kernel, grid=(1,),
            in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
            out_specs=pl.BlockSpec((4,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
            interpret=False,
        )(jnp.zeros((4,), jnp.float32))

    rec = _record_one(run)
    findings = kc.check_record(rec, expected_interpret=True)
    f = _here(findings, "kernel-interpret-routing")
    assert "resolve_interpret" in f.message
    assert kc.check_record(rec, expected_interpret=False) == []


def test_seeded_scratch_mismatch():
    def run():
        pl.pallas_call(
            _noop_kernel, grid=(1,),
            in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
            out_specs=pl.BlockSpec((4,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
            scratch_shapes=[pltpu.VMEM((4,), jnp.float32)],
        )(jnp.zeros((4,), jnp.float32))

    rec = _record_one(run)
    good = kc.check_record(rec, expected_scratch=[((4,), jnp.float32)],
                           expected_sems=0)
    assert good == []
    findings = kc.check_record(rec, expected_scratch=[((8,), jnp.float32)],
                               expected_sems=1)
    assert sum(1 for f in findings if f.rule == "kernel-scratch") == 2
    _here(findings, "kernel-scratch")


def test_contract_run_findings():
    """A case that records nothing, and a case that crashes, both surface
    as kernel-contract-run instead of vacuously passing."""
    def cases():
        return [kc.Case("empty", lambda: None),
                kc.Case("crash", lambda: (_ for _ in ()).throw(
                    ValueError("seeded crash")))]

    contract = kc.KernelContract(
        "seeded", "repro.kernels.flash_attention",
        ("repro.kernels.flash_attention",), cases)
    findings, meta = kc.run_kernel_contracts([contract])
    msgs = [f.message for f in findings
            if f.rule == "kernel-contract-run"]
    assert len(msgs) == 2
    assert any("recorded no pallas_call" in m for m in msgs)
    assert any("seeded crash" in m for m in msgs)
    assert meta["cases"] == 2 and meta["pallas_calls_checked"] == 0


def test_unrouted_interpret_is_reported():
    """A pallas_call reached without consulting resolve_interpret (the
    module spy never fires) is an interpret-routing finding even if the
    flag happens to be right."""
    from repro.kernels.runtime import resolve_interpret

    def run():
        pl.pallas_call(
            _noop_kernel, grid=(1,),
            in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
            out_specs=pl.BlockSpec((4,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
            interpret=resolve_interpret(None),   # right value, wrong route
        )(jnp.zeros((4,), jnp.float32))

    contract = kc.KernelContract(
        "unrouted", "repro.kernels.flash_attention",
        ("repro.kernels.flash_attention",),
        lambda: [kc.Case("direct", run)])
    findings, _ = kc.run_kernel_contracts([contract])
    assert any(f.rule == "kernel-interpret-routing"
               and "never called" in f.message for f in findings)


def test_repo_contracts_cover_all_entry_points():
    mods = {c.module for c in kc.CONTRACTS}
    assert mods == {
        "repro.kernels.paged_decode",
        "repro.kernels.paged_verify",
        "repro.kernels.bitstopper_qk",
        "repro.kernels.flash_attention",
        "repro.kernels.ops",
    }


# ---------------------------------------------------------------------------
# lint rule negatives (seeded fixture tree)
# ---------------------------------------------------------------------------


def _write(root, rel, body):
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(body))
    return rel


def _lint(root, **kw):
    kw.setdefault("read_trees", ("src",))
    kw.setdefault("config_specs", [])
    kw.setdefault("allocator_paths", [])
    return run_lint(root, **kw)


def test_seeded_private_import(tmp_path):
    rel = _write(tmp_path, "src/mod.py", """\
        from repro.models.transformer import _segments
        from repro.models import transformer as T

        def f(p, x, cfg):
            return T._forward_impl(p, x, cfg)
        """)
    findings = _lint(tmp_path)
    got = [(f.file, f.line) for f in findings
           if f.rule == "repo-private-import"]
    assert (rel, 1) in got and (rel, 5) in got


def test_private_self_attribute_not_flagged(tmp_path):
    _write(tmp_path, "src/mod.py", """\
        class Pool:
            def __init__(self):
                self._free = []

            def take(self):
                return self._free.pop()
        """)
    assert _lint(tmp_path) == []


def test_seeded_unread_config_field(tmp_path):
    _write(tmp_path, "src/cfg.py", """\
        import dataclasses

        @dataclasses.dataclass
        class Knobs:
            used: int = 1
            dead: int = 2
        """)
    _write(tmp_path, "src/use.py", """\
        def f(k):
            return k.used
        """)
    findings = _lint(tmp_path,
                     config_specs=[ConfigSpec("src/cfg.py", "Knobs")])
    got = [f for f in findings if f.rule == "repo-config-field-unread"]
    assert len(got) == 1
    assert got[0].file == "src/cfg.py" and got[0].line == 6
    assert "dead" in got[0].message


def test_seeded_allocator_device_ops(tmp_path):
    rel = _write(tmp_path, "src/alloc.py", """\
        import jax.numpy as jnp

        def free_mask(n):
            return jnp.zeros(n)
        """)
    findings = _lint(tmp_path, allocator_paths=[rel])
    got = [f for f in findings if f.rule == "repo-allocator-device-ops"]
    assert len(got) == 1 and got[0].line == 1


def test_seeded_nondeterminism(tmp_path):
    rel = _write(tmp_path, "src/mod.py", """\
        import os
        import random
        import time

        def jitter():
            return random.random() + time.time()

        def cache_fresh(path, built_at):
            return time.time() - os.path.getmtime(path) < 60
        """)
    findings = _lint(tmp_path)
    got = [(f.line, f.message) for f in findings
           if f.rule == "repo-nondeterminism"]
    lines = [ln for ln, _ in got]
    assert 6 in lines                      # random.random() and time.time()
    assert len([ln for ln in lines if ln == 6]) == 2
    assert 9 not in lines                  # mtime comparison is exempt


def test_seeded_tick_wallclock(tmp_path):
    """serving/ tick paths are wall-clock-free by rule (docs/robustness.md):
    importing time or datetime there at all is a finding — engine decisions
    must key on the tick counter, and the watchdog (the one legitimate
    clock consumer) lives in runtime/ with an injected clock."""
    rel = _write(tmp_path, "src/repro/serving/sched.py", """\
        import time
        from datetime import datetime

        def stamp():
            return time.monotonic()
        """)
    findings = _lint(tmp_path, tickpath_dirs=["src/repro/serving"])
    got = [(f.file, f.line) for f in findings
           if f.rule == "repo-tick-wallclock"]
    assert (rel, 1) in got and (rel, 2) in got


def test_seeded_async_boundary(tmp_path):
    """Core serving/ may not import asyncio — the engine is a synchronous
    tick loop; only serving/frontdoor/ (the async door) is exempt."""
    rel = _write(tmp_path, "src/repro/serving/engine.py", """\
        import asyncio
        from concurrent.futures import ThreadPoolExecutor

        async def step_async(eng):
            await asyncio.sleep(0)
        """)
    _write(tmp_path, "src/repro/serving/frontdoor/door.py", """\
        import asyncio

        async def run(door):
            await asyncio.sleep(0)
        """)
    findings = _lint(tmp_path)
    got = [(f.file, f.line) for f in findings
           if f.rule == "repo-async-boundary"]
    assert (rel, 1) in got and (rel, 2) in got
    assert all(f == rel for f, _ in got)   # frontdoor/ is exempt


def test_tick_wallclock_scoped_to_serving(tmp_path):
    # The same imports OUTSIDE the tick-path dirs are not this rule's
    # business (repo-nondeterminism separately polices call sites).
    _write(tmp_path, "src/repro/runtime/dog.py", """\
        import time

        CLOCK = time.monotonic
        """)
    findings = _lint(tmp_path, tickpath_dirs=["src/repro/serving"])
    assert [f for f in findings if f.rule == "repo-tick-wallclock"] == []


def test_lint_clean_on_this_repo():
    root = pathlib.Path(__file__).resolve().parent.parent
    findings = run_lint(root)
    assert findings == [], "\n".join(f.format() for f in findings)


# ---------------------------------------------------------------------------
# report + CLI
# ---------------------------------------------------------------------------


def test_summarize_zero_seeds_all_rules():
    fs = [Finding("pool-refcount", "x.py", 3, "m")]
    counts = summarize(fs, KERNEL_RULES + ["pool-refcount"])
    assert counts["pool-refcount"] == 1
    assert all(counts[r] == 0 for r in KERNEL_RULES)


def test_cli_writes_json_report(tmp_path):
    from repro.analysis.__main__ import ALL_RULES, main
    out = tmp_path / "ANALYSIS.json"
    rc = main(["--only", "pool", "--only", "lint",
               "--root", str(pathlib.Path(__file__).resolve().parent.parent),
               "--out", str(out), "--check"])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["ok"] is True
    assert set(report["rules"]) == set(ALL_RULES)
    assert len(ALL_RULES) >= 8
    # 6 KV-pool scenarios + 2 host-tier (SwapPool ledger) scenarios.
    assert report["pool_scenarios"] == 8


def test_cli_check_fails_on_findings(tmp_path):
    from repro.analysis.__main__ import main
    _write(tmp_path, "src/mod.py", "import time\nt = time.time()\n")
    out = tmp_path / "ANALYSIS.json"
    rc = main(["--only", "lint", "--root", str(tmp_path),
               "--out", str(out), "--check"])
    assert rc == 1
    report = json.loads(out.read_text())
    assert report["ok"] is False
    assert report["rules"]["repo-nondeterminism"] == 1
    assert report["findings"][0]["file"] == "src/mod.py"
