"""Speculative decoding: multi-query paged BESF verify (oracle + fused
Sq-tiled kernel), the PagedEngine draft-verify-accept loop, losslessness
against non-speculative traces, and block-table rollback invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import quantization as qlib
from repro.core.besf import (
    BitStopperConfig,
    besf_attention_decode_paged,
    besf_attention_verify_paged,
)
from repro.kernels.paged_verify import paged_bitstopper_verify
from repro.models import transformer as T
from repro.serving import (
    ContinuousBatchingEngine,
    DraftModelDrafter,
    NGramDrafter,
    PagedEngine,
    Request,
    ServeConfig,
)

BITS = 12


@pytest.fixture(scope="module")
def model():
    cfg = reduced_config("stablelm-1.6b").replace(
        attn_impl="bitstopper_xla", bitstopper=BitStopperConfig(alpha=0.8))
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, lens, max_new=6, seed=0, prefix=None):
    rng = np.random.default_rng(seed)
    out = []
    for L in lens:
        p = rng.integers(0, cfg.vocab, L, dtype=np.int32)
        if prefix is not None:
            p = np.concatenate([prefix, p])
        out.append(Request(prompt=p, max_new_tokens=max_new))
    return out


def _scfg(**kw):
    return ServeConfig(max_len=kw.pop("max_len", 64),
                       max_slots=kw.pop("max_slots", 2),
                       prefill_bucket=kw.pop("prefill_bucket", 8),
                       page_size=kw.pop("page_size", 8), **kw)


# ---------------------------------------------------------------------------
# multi-query paged verify: oracle vs Sq=1 decode, kernel vs oracle
# ---------------------------------------------------------------------------


def _pool_state(seed, P=9, bs=16, Hkv=2, D=16):
    rng = np.random.default_rng(seed)
    k_pool = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)) * 2, jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(P, bs, Hkv, D)), jnp.float32)
    # stale garbage in an unreferenced recycled block, louder than amax
    k_pool = k_pool.at[8].set(50.0)
    k_amax = jnp.max(jnp.abs(k_pool[:8]), axis=(0, 1, 3))
    v_amax = jnp.max(jnp.abs(v_pool), axis=(0, 1, 3))
    return k_pool, v_pool, k_amax, v_amax


def test_verify_oracle_matches_sq1_decode_per_query():
    """Losslessness foundation: every real (slot, query) row of the verify
    oracle is bit-identical to the Sq=1 paged decode at that position with
    that fill level — causal intra-draft masking IS the Sq=1 semantics."""
    k_pool, v_pool, k_amax, v_amax = _pool_state(0)
    rng = np.random.default_rng(1)
    table = jnp.asarray([[1, 2, 3], [4, 5, 0]], jnp.int32)
    Sq, Hq, D = 3, 4, k_pool.shape[-1]
    q = jnp.asarray(rng.normal(size=(2, Sq, Hq, D)), jnp.float32)
    qpos = jnp.asarray([[17, 18, 19], [9, 10, 0]], jnp.int32)
    lengths = jnp.asarray([[18, 19, 20], [10, 11, 0]], jnp.int32)
    cfg = BitStopperConfig(alpha=0.6)
    ver = besf_attention_verify_paged(q, k_pool, v_pool, table, lengths,
                                      qpos, k_amax, v_amax, cfg=cfg)
    for b in range(2):
        for i in range(Sq):
            if int(lengths[b, i]) == 0:       # padding query: no work
                assert np.asarray(ver.rounds)[b, i].sum() == 0
                continue
            dec = besf_attention_decode_paged(
                q[b:b + 1, i], k_pool, v_pool, table[b:b + 1],
                lengths[b:b + 1, i], qpos[b:b + 1, i], k_amax, v_amax,
                cfg=cfg)
            np.testing.assert_array_equal(np.asarray(dec.out[0]),
                                          np.asarray(ver.out)[b, i])
            np.testing.assert_array_equal(np.asarray(dec.rounds[0]),
                                          np.asarray(ver.rounds)[b, i])
            np.testing.assert_array_equal(np.asarray(dec.survivors[0]),
                                          np.asarray(ver.survivors)[b, i])
            np.testing.assert_array_equal(np.asarray(dec.v_fetched[0]),
                                          np.asarray(ver.v_fetched)[b, i])


@pytest.mark.parametrize("alpha,window,G", [
    (0.2, None, 1),
    (0.6, None, 2),
    (0.8, 24, 2),
])
def test_verify_kernel_matches_oracle(alpha, window, G):
    """Bit-exact kernel/oracle parity on adversarial tables: a shared
    physical block mapped by two rows, recycled stale garbage, a row
    ending mid-page, and a padding (zero-length) query.  Per-query rounds,
    survivors and V-fetch decisions are bitwise; out agrees to f32
    epsilon (same contract as the Sq=1 decode kernel tests)."""
    k_pool, v_pool, k_amax, v_amax = _pool_state(2)
    rng = np.random.default_rng(3)
    Hkv, D = k_pool.shape[2], k_pool.shape[3]
    Hq = Hkv * G
    kq_pool = qlib.pack_pool_planes(k_pool, k_amax, BITS)
    table = jnp.asarray([[1, 2, 3, 4], [1, 5, 6, 0], [7, 3, 0, 0]],
                        jnp.int32)
    Sq = 3
    q = jnp.asarray(rng.normal(size=(3, Sq, Hq, D)) * 2, jnp.float32)
    qpos = jnp.asarray([[61, 62, 63], [38, 39, 40], [17, 18, 0]], jnp.int32)
    lengths = jnp.asarray([[62, 63, 64], [39, 40, 41], [18, 19, 0]],
                          jnp.int32)
    cfg = BitStopperConfig(alpha=alpha)
    ora = besf_attention_verify_paged(q, k_pool, v_pool, table, lengths,
                                      qpos, k_amax, v_amax, cfg=cfg,
                                      window=window)
    ker = paged_bitstopper_verify(q, kq_pool, v_pool, table, lengths, qpos,
                                  k_amax, v_amax, cfg=cfg, window=window,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(ora.rounds),
                                  np.asarray(ker.rounds))
    np.testing.assert_array_equal(np.asarray(ora.survivors),
                                  np.asarray(ker.survivors))
    np.testing.assert_array_equal(np.asarray(ora.v_fetched),
                                  np.asarray(ker.v_fetched))
    np.testing.assert_allclose(np.asarray(ora.out), np.asarray(ker.out),
                               atol=1e-6, rtol=1e-6)
    # pages past a query's position/fill are never touched
    rounds = np.asarray(ora.rounds)
    assert (rounds[2, :, 2:] == 0).all()      # row 2 ends mid page 2
    assert (rounds[2, 2] == 0).all()          # padding query: nothing
    assert (rounds[1, 0, 3] == 0)             # null table entry


def test_verify_kernel_amortizes_plane_fetches():
    """The fused kernel's union-liveness DMA sharing: per-query rounds
    match the oracle exactly, so the modeled plane traffic of the whole
    draft block (max over queries per page — one fetch serves all) is
    strictly less than the sum of per-query fetches."""
    k_pool, v_pool, k_amax, v_amax = _pool_state(4)
    rng = np.random.default_rng(5)
    Hkv, D = k_pool.shape[2], k_pool.shape[3]
    kq_pool = qlib.pack_pool_planes(k_pool, k_amax, BITS)
    table = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    Sq = 4
    q = jnp.asarray(rng.normal(size=(1, Sq, Hkv, D)) * 2, jnp.float32)
    qpos = jnp.asarray([[60, 61, 62, 63]], jnp.int32)
    lengths = qpos + 1
    cfg = BitStopperConfig(alpha=0.4)
    ker = paged_bitstopper_verify(q, kq_pool, v_pool, table, lengths, qpos,
                                  k_amax, v_amax, cfg=cfg, interpret=True)
    rounds = np.asarray(ker.rounds)[0]                    # [Sq, MB]
    shared = rounds.max(axis=0).sum()                     # one DMA stream
    separate = rounds.sum()                               # Sq=1 x Sq cost
    assert shared < separate, (shared, separate)


def test_verify_kernel_stats_false_matches():
    k_pool, v_pool, k_amax, v_amax = _pool_state(6)
    rng = np.random.default_rng(7)
    Hkv, D = k_pool.shape[2], k_pool.shape[3]
    kq_pool = qlib.pack_pool_planes(k_pool, k_amax, BITS)
    table = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    q = jnp.asarray(rng.normal(size=(2, 2, Hkv, D)), jnp.float32)
    qpos = jnp.asarray([[40, 41], [20, 0]], jnp.int32)
    lengths = jnp.asarray([[41, 42], [21, 0]], jnp.int32)
    cfg = BitStopperConfig(alpha=0.6)
    a = paged_bitstopper_verify(q, kq_pool, v_pool, table, lengths, qpos,
                                k_amax, v_amax, cfg=cfg, interpret=True,
                                stats=False)
    b = paged_bitstopper_verify(q, kq_pool, v_pool, table, lengths, qpos,
                                k_amax, v_amax, cfg=cfg, interpret=True)
    assert a.survivors is None and a.v_fetched is None
    np.testing.assert_array_equal(np.asarray(a.out), np.asarray(b.out))
    np.testing.assert_array_equal(np.asarray(a.rounds), np.asarray(b.rounds))


# ---------------------------------------------------------------------------
# engine-level losslessness: speculative == non-speculative, bit for bit
# ---------------------------------------------------------------------------


def _serve(cfg, params, reqs, seed=0, drafter=None, **kw):
    eng = PagedEngine(cfg, params, _scfg(**kw), drafter=drafter)
    eng.generate(reqs, seed=seed)
    return eng


@pytest.mark.parametrize("speculative", ["ngram", "draft"])
def test_spec_trace_bitident_greedy(model, speculative):
    """Acceptance: speculative serving is lossless — greedy traces are
    bit-identical to non-speculative serving for both drafters (including
    cold-start scale-growth bailout ticks)."""
    cfg, params = model
    ref = _reqs(cfg, (5, 11, 17))
    _serve(cfg, params, ref)
    spec = _reqs(cfg, (5, 11, 17))
    eng = _serve(cfg, params, spec, speculative=speculative, draft_k=3)
    assert [r.generated for r in spec] == [r.generated for r in ref]
    assert eng.pool.live_blocks() == 0
    assert eng.pool.available() == eng.pool.capacity


@pytest.mark.parametrize("speculative", ["ngram", "draft"])
def test_spec_trace_bitident_sampled(model, speculative):
    """Seeded sampling: draft-block token n draws from the same
    fold_in(fold_in(seed, rid), n) key as non-speculative decode, so
    sampled traces are identical too."""
    cfg, params = model
    ref = _reqs(cfg, (5, 11), max_new=5)
    _serve(cfg, params, ref, seed=7, temperature=1.0)
    spec = _reqs(cfg, (5, 11), max_new=5)
    _serve(cfg, params, spec, seed=7, temperature=1.0,
           speculative=speculative, draft_k=3)
    assert [r.generated for r in spec] == [r.generated for r in ref]


def test_spec_fused_kernel_matches_fallback_and_accepts(model):
    """Self-drafting with the target model (acceptance 1.0 under greedy
    once quant scales warm up): the fused Sq-tiled verify kernel and the
    pure-JAX fallback serve identical tokens, actually accept drafts, and
    finish in fewer ticks than tokens emitted."""
    cfg, params = model
    outs, engines = [], []
    for fused in (True, False):
        eng = PagedEngine(cfg, params,
                          _scfg(speculative="draft", draft_k=3,
                                fused_decode=fused))
        # Warm the pool-wide quant scales so accept ticks dominate.
        _ = eng.generate(_reqs(cfg, (24,), max_new=8, seed=9), seed=0)
        reqs = _reqs(cfg, (5, 11), max_new=8)
        eng.generate(reqs, seed=0)
        outs.append([r.generated for r in reqs])
        engines.append(eng)
    assert outs[0] == outs[1]
    ref = _reqs(cfg, (5, 11), max_new=8)
    warm = PagedEngine(cfg, params, _scfg())
    warm.generate(_reqs(cfg, (24,), max_new=8, seed=9), seed=0)
    warm.generate(ref, seed=0)
    assert outs[0] == [r.generated for r in ref]
    for eng in engines:
        assert eng.counters["spec_accepted"] > 0
        assert eng.counters["spec_accepted"] == eng.counters["spec_proposed"]


def test_spec_with_chunked_prefill_and_shared_prefix(model):
    """Speculation composes with chunked prefill and prefix sharing:
    traces still match the non-speculative engine, prefix blocks still
    hit."""
    cfg, params = model
    sysp = np.random.default_rng(42).integers(0, cfg.vocab, 24,
                                              dtype=np.int32)
    kw = dict(prefill_chunk=8, max_len=96)
    ref = _reqs(cfg, (3, 7, 5), max_new=4, prefix=sysp)
    _serve(cfg, params, ref, **kw)
    spec = _reqs(cfg, (3, 7, 5), max_new=4, prefix=sysp)
    eng = _serve(cfg, params, spec, speculative="ngram", draft_k=4, **kw)
    assert [r.generated for r in spec] == [r.generated for r in ref]
    assert eng.counters["prefix_hit_tokens"] > 0
    assert eng.pool.live_blocks() == 0


def test_spec_snug_recycled_pool(model):
    """A pool snug enough that physical blocks recycle mid-trace: rolled-
    back draft-tail blocks re-enter circulation and must leak no stale KV
    into later requests — traces equal a fresh-pool run bit for bit."""
    cfg, params = model
    kw = dict(max_slots=2, pool_blocks=7, prefix_sharing=False,
              speculative="draft", draft_k=3)
    eng = PagedEngine(cfg, params, _scfg(**kw))
    eng.generate(_reqs(cfg, (12, 9), max_new=4, seed=3), seed=0)
    assert eng.pool.alloc_count >= 4
    reused = _reqs(cfg, (11, 7), max_new=4, seed=4)
    eng.generate(reused, seed=0)

    fresh = _reqs(cfg, (11, 7), max_new=4, seed=4)
    # Non-speculative, fresh pool — but same-engine amax warm-up matters
    # for bit-identity, so replay the same two batches without drafts.
    ref_eng = PagedEngine(cfg, params, _scfg(max_slots=2, pool_blocks=7,
                                             prefix_sharing=False))
    ref_eng.generate(_reqs(cfg, (12, 9), max_new=4, seed=3), seed=0)
    ref_eng.generate(fresh, seed=0)
    assert [r.generated for r in reused] == [r.generated for r in fresh]


def test_spec_eos_truncation(model):
    """EOS inside an accepted draft block truncates the emission exactly
    where non-speculative serving would have stopped."""
    cfg, params = model
    free = _reqs(cfg, (9,), max_new=8, seed=1)
    _serve(cfg, params, free)
    eos = free[0].generated[2]
    ref = _reqs(cfg, (9,), max_new=8, seed=1)
    _serve(cfg, params, ref, eos_id=int(eos))
    spec = _reqs(cfg, (9,), max_new=8, seed=1)
    _serve(cfg, params, spec, eos_id=int(eos), speculative="draft",
           draft_k=4)
    assert spec[0].generated == ref[0].generated == free[0].generated[:3]


# ---------------------------------------------------------------------------
# block-table rollback invariants
# ---------------------------------------------------------------------------


class _GarbageDrafter:
    """Adversarial drafter: always proposes k maximally wrong tokens so
    every tick allocates draft-tail blocks and rolls them all back."""

    def __init__(self, vocab):
        self.vocab = vocab

    def propose(self, context, k):
        return [(int(context[-1]) + 1 + i) % self.vocab for i in range(k)]


def test_spec_rollback_returns_tail_blocks(model):
    """Rejected draft tails: the tick's speculative blocks return to the
    free list with reservations restored (mid-trace the pool never leaks),
    and the served trace is still bit-identical to plain decode."""
    cfg, params = model
    ref = _reqs(cfg, (9, 14), max_new=6)
    _serve(cfg, params, ref)
    spec = _reqs(cfg, (9, 14), max_new=6)
    eng = PagedEngine(
        cfg, params,
        _scfg(speculative="ngram", draft_k=7, max_len=96),
        drafter=_GarbageDrafter(cfg.vocab))
    eng.generate(spec, seed=0)
    assert [r.generated for r in spec] == [r.generated for r in ref]
    # garbage drafts crossed page boundaries: speculative blocks were
    # materialized and rolled back (more allocs than plain serving needs)
    plain = PagedEngine(cfg, params, _scfg(max_len=96))
    plain.generate(_reqs(cfg, (9, 14), max_new=6), seed=0)
    assert eng.counters["spec_proposed"] > eng.counters["spec_accepted"]
    assert eng.pool.alloc_count > plain.pool.alloc_count
    assert eng.pool.live_blocks() == 0
    assert eng.pool.available() == eng.pool.capacity
    assert (eng.table == 0).all()


def test_spec_rollback_never_crosses_shared_prefix(model):
    """Prefix-shared blocks sit below the decode region; rollback frees
    only exclusively-owned draft-tail blocks (kv_pool.rollback enforces
    it), and the shared blocks stay published and resurrectable."""
    cfg, params = model
    sysp = np.random.default_rng(41).integers(0, cfg.vocab, 16,
                                              dtype=np.int32)
    eng = PagedEngine(
        cfg, params, _scfg(speculative="ngram", draft_k=6, max_len=96),
        drafter=_GarbageDrafter(cfg.vocab))
    eng.generate(_reqs(cfg, (4, 6), max_new=5, prefix=sysp), seed=0)
    assert eng.pool.live_blocks() == 0
    # the system-prompt blocks survived every rollback: a follow-up batch
    # still resurrects them from the LRU cache
    second = _reqs(cfg, (5,), max_new=4, seed=5, prefix=sysp)
    eng.generate(second, seed=0)
    assert eng.counters["prefix_hit_tokens"] >= 16


def test_spec_config_validation(model):
    cfg, params = model
    with pytest.raises(ValueError):
        ServeConfig(speculative="mtp")
    with pytest.raises(ValueError):
        ServeConfig(speculative="ngram", draft_k=0)
    with pytest.raises(ValueError):
        # bitstopper speculation needs the pool-wide quant state
        PagedEngine(cfg, params, _scfg(speculative="ngram", page_size=6))
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(cfg, params,
                                 ServeConfig(speculative="ngram"))
    with pytest.raises(ValueError):
        PagedEngine(cfg, params, _scfg(), drafter=NGramDrafter())


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------


def test_ngram_drafter_prompt_lookup():
    d = NGramDrafter(max_n=3, min_n=1)
    ctx = np.asarray([7, 1, 2, 3, 9, 1, 2, 3], np.int32)
    # suffix [1,2,3] matched at position 1 -> continuation [9, 1, 2]
    assert d.propose(ctx, 3) == [9, 1, 2]
    assert d.propose(ctx, 1) == [9]
    # no repeat anywhere -> nothing proposed
    assert d.propose(np.arange(10, dtype=np.int32), 4) == []
    # falls back to shorter n-grams
    assert d.propose(np.asarray([5, 9, 5], np.int32), 2) == [9, 5]


def test_draft_model_drafter_greedy(model):
    """Self-draft proposals equal the target's own greedy continuation
    (cache-free forward), for any context length bucket."""
    cfg, params = model
    d = DraftModelDrafter(cfg, params, bucket=8)
    rng = np.random.default_rng(0)
    ctx = rng.integers(0, cfg.vocab, 11, dtype=np.int32)
    got = d.propose(ctx, 3)
    seq = list(ctx)
    for _ in range(3):
        logits, _, _ = T.forward(params, jnp.asarray(seq)[None], cfg)
        seq.append(int(jnp.argmax(logits[0, -1])))
    assert got == seq[len(ctx):]
