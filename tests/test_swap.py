"""Lossless KV memory hierarchy (docs/serving.md "Memory hierarchy").

Swap-to-host preemption resume and persistent-prefix-store warm starts
must be token-bit-identical to chunked-prefill recompute on every
serving path: greedy, seeded sampling, shared prefixes, speculative
decoding, and the fused BESF decode kernel plus its gather fallback.
The sweep also pins the fallback ladder (budget refusal, non-contiguous
victims) and the tier accounting contract (`kv_bytes_resident` stays
device-only; host/disk tiers report separately)."""

import os
import tempfile

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.models import transformer as T
from repro.serving import ContinuousBatchingEngine, PagedEngine, Request, \
    ServeConfig
from repro.serving.engine import _amax_leaves


@pytest.fixture(scope="module")
def model():
    cfg = reduced_config("stablelm-1.6b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def bitstopper_model(model):
    cfg, params = model
    return cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.8)), params


def _reqs(cfg, lens, max_new=16, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab, L, dtype=np.int32),
                    max_new_tokens=max_new)
            for L in lens]


def _paged(cfg, params, **kw):
    scfg = ServeConfig(max_len=kw.pop("max_len", 64),
                       max_slots=kw.pop("max_slots", 2),
                       prefill_bucket=kw.pop("prefill_bucket", 8),
                       page_size=kw.pop("page_size", 8), **kw)
    return PagedEngine(cfg, params, scfg)


# Pool sized so the three requests' worst-case reservations cannot
# coexist but their actual footprints can (same shape as the
# oversubscription suite in test_serving.py) — decode outgrows the
# reservations and a mid-decode claim must preempt a victim.
_OS = dict(max_slots=3, page_size=8, pool_blocks=10, oversubscribe=True)
_SWAP = dict(swap_host_bytes=1 << 22)


def _swap_vs_recompute(cfg, params, make_reqs, seed=0, **kw):
    """Serve the same oversubscribed trace twice — recompute-resume
    (no swap tier) and swap-resume — and return both engines + outputs."""
    rec_eng = _paged(cfg, params, **_OS, **kw)
    rec = make_reqs()
    rec_eng.generate(rec, seed=seed)
    swp_eng = _paged(cfg, params, **_OS, **_SWAP, **kw)
    swp = make_reqs()
    swp_eng.generate(swp, seed=seed)
    return rec_eng, [r.generated for r in rec], \
        swp_eng, [r.generated for r in swp]


# ---------------------------------------------------------------------------
# swap-resume vs recompute-resume: bit-identity across serving paths
# ---------------------------------------------------------------------------


def test_swap_resume_bitident_greedy(model):
    """Acceptance: swap-resume replays the exact trace recompute-resume
    produces, while actually skipping the resume prefill work."""
    cfg, params = model
    rec_eng, rec, swp_eng, swp = _swap_vs_recompute(
        cfg, params, lambda: _reqs(cfg, (12, 9, 11)))
    assert rec_eng.counters["preemptions"] >= 1
    assert swp_eng.counters["swap_outs"] >= 1
    assert swp_eng.counters["swap_ins"] >= 1
    assert swp_eng.counters["swap_in_tokens"] > 0
    assert rec == swp
    # the spliced tokens were NOT re-prefilled
    assert (swp_eng.counters["prefill_chunks"]
            < rec_eng.counters["prefill_chunks"])
    # every swap record was consumed; device pool drains clean
    assert swp_eng._swap.bytes_used == 0
    assert swp_eng.pool.available() == swp_eng.pool.capacity


def test_swap_resume_bitident_sampled(model):
    """Seeded sampling: keys are (seed, rid, token index), so the swap
    splice cannot shift the sampled trace either."""
    cfg, params = model
    _, rec, swp_eng, swp = _swap_vs_recompute(
        cfg, params, lambda: _reqs(cfg, (12, 9, 11)),
        seed=7, temperature=1.0)
    assert swp_eng.counters["swap_ins"] >= 1
    assert rec == swp


def test_swap_resume_bitident_shared_prefix(model):
    """Only exclusively-owned blocks swap: shared system-prompt blocks
    stay registered on device, resume re-maps them for free, and the
    swapped tail still splices bit-identically."""
    cfg, params = model
    sys_prompt = np.random.default_rng(42).integers(
        0, cfg.vocab, 16, dtype=np.int32)

    def reqs():
        r = np.random.default_rng(5)
        return [Request(prompt=np.concatenate(
                            [sys_prompt,
                             r.integers(0, cfg.vocab, L, dtype=np.int32)]),
                        max_new_tokens=16)
                for L in (3, 7, 5)]

    kw = dict(max_slots=3, page_size=8, pool_blocks=11, oversubscribe=True)
    rec_eng = _paged(cfg, params, **kw)
    rec = reqs()
    rec_eng.generate(rec, seed=0)
    swp_eng = _paged(cfg, params, **kw, **_SWAP)
    swp = reqs()
    swp_eng.generate(swp, seed=0)
    assert swp_eng.counters["preemptions"] >= 1
    assert swp_eng.counters["prefix_hit_tokens"] > 0
    assert [r.generated for r in rec] == [r.generated for r in swp]
    assert swp_eng.pool.available() == swp_eng.pool.capacity


def test_swap_resume_bitident_speculative(model):
    """Speculative ngram decoding on top of swap-resume: accepted draft
    tokens land in swapped-then-restored blocks without perturbation."""
    cfg, params = model
    _, rec, swp_eng, swp = _swap_vs_recompute(
        cfg, params, lambda: _reqs(cfg, (12, 9, 11)),
        speculative="ngram", draft_k=3)
    assert swp_eng.counters["preemptions"] >= 1
    assert rec == swp
    assert swp_eng.pool.available() == swp_eng.pool.capacity


def test_swap_resume_bitident_fused_and_fallback(bitstopper_model):
    """The sparse path: packed ``kq`` plane rows travel with the swap
    record, so the fused kernel decodes restored blocks bit-identically —
    and the gather fallback agrees."""
    cfgb, params = bitstopper_model
    outs = []
    for fused in (True, False):
        _, rec, swp_eng, swp = _swap_vs_recompute(
            cfgb, params, lambda: _reqs(cfgb, (12, 9, 11)),
            fused_decode=fused)
        assert swp_eng.counters["swap_ins"] >= 1
        assert rec == swp
        outs.append(swp)
    assert outs[0] == outs[1]


def test_swap_quant_grid_growth_repacks(bitstopper_model):
    """Quant-grid case: the pool amax grows between swap-out and swap-in
    (another request's prefill widens the grid while the victim is on the
    host).  The stored ``kq`` planes are then stale — the engine must
    drop them and repack the f32 rows under the current scales, and the
    trace still matches recompute bit for bit."""
    cfgb, params = bitstopper_model
    # seed 6 chosen by sweep: its trace grows k_amax between the
    # victim's swap-out and its resume (verified by the probe below).
    make = lambda: _reqs(cfgb, (12, 9, 11), seed=6)  # noqa: E731
    rec_eng = _paged(cfgb, params, **_OS)
    rec = make()
    rec_eng.generate(rec, seed=0)

    swp_eng = _paged(cfgb, params, **_OS, **_SWAP)
    grew, orig = [], swp_eng._swap_in

    def probe(req, row, ctx, m, resumed):
        record = swp_eng._swap.get(req.rid)
        if record is not None:
            cur = [np.asarray(a, np.float32)
                   for a in _amax_leaves(swp_eng.caches)]
            grew.append(not all(np.array_equal(c, r)
                                for c, r in zip(cur, record["amax"])))
        return orig(req, row, ctx, m, resumed)

    swp_eng._swap_in = probe
    swp = make()
    swp_eng.generate(swp, seed=0)
    assert swp_eng.counters["swap_ins"] >= 1
    assert any(grew), "trace no longer exercises the stale-planes path"
    assert [r.generated for r in rec] == [r.generated for r in swp]


def test_swap_budget_refusal_falls_back_to_recompute(model):
    """A swap pool too small for the victim's record refuses the put;
    the preemption falls back to recompute and stays lossless."""
    cfg, params = model
    rec_eng = _paged(cfg, params, **_OS)
    rec = _reqs(cfg, (12, 9, 11))
    rec_eng.generate(rec, seed=0)
    tiny = _paged(cfg, params, swap_host_bytes=64, **_OS)
    swp = _reqs(cfg, (12, 9, 11))
    tiny.generate(swp, seed=0)
    assert tiny.counters["swap_fallbacks"] >= 1
    assert tiny.counters["swap_ins"] == 0
    assert tiny._swap.refused_count >= 1
    assert [r.generated for r in rec] == [r.generated for r in swp]


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=40),
       lens=st.sampled_from([(12, 9, 11), (13, 10, 9), (11, 12, 10)]),
       temperature=st.sampled_from([0.0, 1.0]))
def test_swap_resume_bitident_property(model, seed, lens, temperature):
    """Property sweep: random prompts + either sampling mode — swap-
    resume never diverges from recompute-resume."""
    cfg, params = model
    _, rec, swp_eng, swp = _swap_vs_recompute(
        cfg, params, lambda: _reqs(cfg, lens, seed=seed),
        seed=seed, temperature=temperature)
    assert rec == swp
    assert swp_eng.pool.available() == swp_eng.pool.capacity
    assert swp_eng._swap.bytes_used == 0


# ---------------------------------------------------------------------------
# persistent prefix store: cross-restart warm starts
# ---------------------------------------------------------------------------

# prefill_chunk must not exceed the stored prefix for injection to cover
# a chunk-group boundary (the engine refuses mid-chunk splices so the
# host-side scale replay matches recompute's chunk boundaries exactly).
_STORE = dict(max_len=64, max_slots=2, prefill_bucket=8, page_size=8,
              prefill_chunk=8)


def _store_reqs(cfg, sys_prompt, lens=(6, 9), max_new=8, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(0, cfg.vocab, L, dtype=np.int32)]),
                    max_new_tokens=max_new)
            for L in lens]


def _sys_prompt(cfg, n=16, seed=42):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab, n, dtype=np.int32)


def test_prefix_store_warm_start_bitident(model, tmp_path):
    """A fresh engine pointed at a populated store serves the same
    system prompt bit-identically to a cold engine, with fewer prefill
    chunks (the stored blocks splice instead of recomputing)."""
    cfg, params = model
    sys_prompt = _sys_prompt(cfg)
    first = _paged(cfg, params, prefix_store_dir=str(tmp_path), **_STORE)
    first.generate(_store_reqs(cfg, sys_prompt), seed=0)
    assert first.flush_prefixes() >= 2          # 16-token prefix = 2 blocks

    cold_eng = _paged(cfg, params, **_STORE)
    cold = _store_reqs(cfg, sys_prompt)
    cold_eng.generate(cold, seed=0)
    warm_eng = _paged(cfg, params, prefix_store_dir=str(tmp_path), **_STORE)
    warm = _store_reqs(cfg, sys_prompt)
    warm_eng.generate(warm, seed=0)

    assert warm_eng.counters["prefix_store_hits"] >= 1
    assert warm_eng.counters["prefix_store_tokens"] >= 16
    assert [r.generated for r in cold] == [r.generated for r in warm]
    assert (warm_eng.counters["prefill_chunks"]
            < cold_eng.counters["prefill_chunks"])


def test_prefix_store_warm_start_bitstopper(bitstopper_model, tmp_path):
    """The sparse path across a restart: injected blocks replay the
    quant-scale growth rule host-side with recompute's exact chunk
    boundaries, so the warmed engine's grid — and every served token —
    matches the cold run."""
    cfgb, params = bitstopper_model
    sys_prompt = _sys_prompt(cfgb)
    first = _paged(cfgb, params, prefix_store_dir=str(tmp_path), **_STORE)
    first.generate(_store_reqs(cfgb, sys_prompt), seed=0)
    first.flush_prefixes()

    cold_eng = _paged(cfgb, params, **_STORE)
    cold = _store_reqs(cfgb, sys_prompt)
    cold_eng.generate(cold, seed=0)
    warm_eng = _paged(cfgb, params, prefix_store_dir=str(tmp_path), **_STORE)
    warm = _store_reqs(cfgb, sys_prompt)
    warm_eng.generate(warm, seed=0)
    assert warm_eng.counters["prefix_store_hits"] >= 1
    assert [r.generated for r in cold] == [r.generated for r in warm]
    # and the warmed quant scales converged to the cold engine's
    for a, b in zip(_amax_leaves(cold_eng.caches),
                    _amax_leaves(warm_eng.caches)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefix_store_resumed_request_zero_prefill(model, tmp_path):
    """A resumed request whose whole context is block-aligned and stored
    re-materializes with ZERO prefill chunks — decode continues directly
    on the spliced blocks, matching the recompute continuation."""
    cfg, params = model
    sys_prompt = _sys_prompt(cfg)
    first = _paged(cfg, params, prefix_store_dir=str(tmp_path), **_STORE)
    first.generate(_store_reqs(cfg, sys_prompt), seed=0)
    first.flush_prefixes()

    def resumed():
        r = Request(prompt=sys_prompt[:15].copy(), max_new_tokens=4)
        # resume ctx = prompt + generated[:-1] = 16 tokens = 2 full
        # blocks, both of which sit in the store
        r.generated = [int(sys_prompt[15]), 42]
        return r

    ref_eng = _paged(cfg, params, **_STORE)
    ref = resumed()
    ref_eng.generate([ref], seed=0)
    warm_eng = _paged(cfg, params, prefix_store_dir=str(tmp_path), **_STORE)
    got = resumed()
    warm_eng.generate([got], seed=0)
    assert warm_eng.counters["prefill_chunks"] == 0
    assert ref_eng.counters["prefill_chunks"] > 0
    assert ref.generated == got.generated


def test_prefix_host_tier_spills_to_disk(model, tmp_path):
    """The tier cascade: device LRU eviction lands registered blocks in
    the host tier; host-tier pressure spills them on to disk; a warm
    engine still recovers them losslessly from whichever tier holds
    them."""
    cfg, params = model
    sys_prompt = _sys_prompt(cfg)
    # Pool snug enough that parked registered blocks get LRU-stolen by
    # later admissions; host tier fits roughly one block record, so the
    # second eviction cascades a disk spill through the atomic store.
    eng = _paged(cfg, params, prefix_store_dir=str(tmp_path),
                 prefix_host_bytes=1 << 14, pool_blocks=8, **_STORE)
    eng.generate(_store_reqs(cfg, sys_prompt, lens=(9, 11, 10, 9, 11),
                             max_new=16, seed=8), seed=0)
    assert eng.counters["prefix_spills"] >= 1
    assert eng._prefix_host.evict_count >= 1
    rep = eng.memory_report()
    assert rep["disk_prefix_bytes"] > 0
    assert rep["host_prefix_bytes"] <= 1 << 14
    eng.flush_prefixes()

    cold_eng = _paged(cfg, params, **_STORE)
    cold = _store_reqs(cfg, sys_prompt)
    cold_eng.generate(cold, seed=0)
    warm_eng = _paged(cfg, params, prefix_store_dir=str(tmp_path), **_STORE)
    warm = _store_reqs(cfg, sys_prompt)
    warm_eng.generate(warm, seed=0)
    assert warm_eng.counters["prefix_store_hits"] >= 1
    assert [r.generated for r in cold] == [r.generated for r in warm]


# ---------------------------------------------------------------------------
# tier accounting + config surface
# ---------------------------------------------------------------------------


def test_memory_report_tiers_are_disjoint(model, tmp_path):
    """`kv_bytes_resident` stays device-only by contract; swapped and
    spilled bytes appear in their own fields and never leak into it."""
    cfg, params = model
    eng = _paged(cfg, params, prefix_store_dir=str(tmp_path), **_OS, **_SWAP)
    plain = _paged(cfg, params, **_OS)
    for e in (eng, plain):
        reqs = _reqs(cfg, (12, 9, 11))
        e.generate(reqs, seed=0)
    assert eng.counters["swap_ins"] >= 1
    rep = eng.memory_report()
    assert rep["device_bytes"] == eng.kv_bytes_resident(peak=False)
    assert rep["device_bytes_peak"] == eng.kv_bytes_resident(peak=True)
    # hierarchy tiers never inflate the device-resident figure
    assert (eng.kv_bytes_resident(peak=True)
            == plain.kv_bytes_resident(peak=True))
    # the victim's record really lived on the host at some point...
    assert rep["host_swap_bytes_peak"] > 0
    # ...and was fully consumed by swap-in
    assert rep["host_swap_bytes"] == 0


def test_hierarchy_config_validation(model, tmp_path):
    cfg, params = model
    with pytest.raises(ValueError):
        ServeConfig(swap_host_bytes=-1)
    with pytest.raises(ValueError):
        ServeConfig(prefix_host_bytes=-1)
    # swap captures preemption victims; only oversubscription preempts
    with pytest.raises(ValueError):
        ServeConfig(swap_host_bytes=1 << 20)
    # prefix tiers extend the prefix registry; nothing to spill without it
    with pytest.raises(ValueError):
        ServeConfig(prefix_store_dir="/tmp/x", prefix_sharing=False)
    with pytest.raises(ValueError):
        ServeConfig(prefix_host_bytes=1 << 20, prefix_sharing=False)
    # the contiguous engine has no paged pool to tier
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(cfg, params, ServeConfig(
            max_len=64, prefix_store_dir=str(tmp_path)))
    # flush_prefixes requires a configured store directory
    with pytest.raises(RuntimeError):
        _paged(cfg, params).flush_prefixes()
