"""Unit + property tests for INT12 quantization and bit-plane decomposition."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import quantization as qlib


def test_plane_weights_msb_negative():
    w = qlib.plane_weights(12)
    assert w[0] == -(2 ** 11)
    assert w[-1] == 1
    assert float(jnp.sum(w)) == -1  # -2^11 + (2^11 - 1)


@pytest.mark.parametrize("bits", [4, 8, 12])
def test_bitplane_roundtrip_exhaustive_range(bits):
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    vals = jnp.arange(lo, hi + 1, dtype=jnp.int32)
    planes = qlib.to_bitplanes(vals, bits)
    back = qlib.from_bitplanes(planes)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(vals))


def test_partial_value_monotone_prefix():
    vals = jnp.array([-2048, -1, 0, 1, 2047, 1234, -777], jnp.int32)
    planes = qlib.to_bitplanes(vals, 12)
    full = qlib.partial_value(planes, 11)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(vals))
    # partial + remaining nonneg bits <= full for every prefix
    for r in range(12):
        part = np.asarray(qlib.partial_value(planes, r))
        rem = 2 ** (11 - r) - 1
        assert np.all(part <= np.asarray(vals))
        assert np.all(np.asarray(vals) <= part + rem)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_quantize_dequantize_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(37,)) * rng.uniform(0.1, 10))
    q, params = qlib.quantize(x, 12)
    assert int(jnp.max(q)) <= params.qmax and int(jnp.min(q)) >= params.qmin
    err = jnp.max(jnp.abs(qlib.dequantize(q, params) - x))
    assert float(err) <= float(params.scale) * 0.5 + 1e-7


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 16, 64]))
def test_pack_unpack_seq_roundtrip(seed, S):
    rng = np.random.default_rng(seed)
    d = 16
    q = jnp.asarray(rng.integers(-2048, 2048, size=(S, d)), jnp.int32)
    planes = qlib.to_bitplanes(q, 12)
    packed = qlib.pack_planes_seq(planes)
    assert packed.shape == (12, S // 8, d)
    np.testing.assert_array_equal(
        np.asarray(qlib.unpack_planes_seq(packed)), np.asarray(planes)
    )


def test_pack_rejects_unaligned():
    planes = jnp.zeros((12, 9, 4), jnp.uint8)
    with pytest.raises(AssertionError):
        qlib.pack_planes_seq(planes)
