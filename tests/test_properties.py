"""Hypothesis property tests on the system's core invariants.

The paper's correctness rests on one exact statement: at every bit round r,

    A^r_ij + M_i^{r,min}  <=  A_ij  <=  A^r_ij + M_i^{r,max}

(the bit-level uncertainty margin is a true interval bound).  Everything
else — mode survival, conservativeness of the block adaptation — follows.
These tests check the invariants on adversarial integer inputs, not just
happy-path floats.
"""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import margins as margins_lib
from repro.core import quantization as qlib
from repro.core.besf import BitStopperConfig, besf_attention
from repro.core.block_adaptation import block_bitstopper_attention

_settings = settings(max_examples=25, deadline=None)

ints12 = st.integers(min_value=-2048, max_value=2047)


@st.composite
def int_vectors(draw, max_d=16):
    d = draw(st.integers(2, max_d))
    q = draw(st.lists(ints12, min_size=d, max_size=d))
    k = draw(st.lists(ints12, min_size=d, max_size=d))
    return np.array(q, np.int32), np.array(k, np.int32)


@given(int_vectors())
@_settings
def test_margin_interval_bound_every_round(qk):
    """lower <= exact <= upper, bit-for-bit, at every round."""
    q, k = qk
    bits = 12
    planes = np.asarray(qlib.to_bitplanes(jnp.asarray(k), bits))
    m_min, m_max = margins_lib.bit_margins(jnp.asarray(q)[None, :], bits)
    m_min, m_max = np.asarray(m_min)[:, 0], np.asarray(m_max)[:, 0]
    exact = int(q.astype(np.int64) @ k.astype(np.int64))
    w = np.array([2 ** (bits - 1 - r) for r in range(bits)], np.int64)
    w[0] = -w[0]
    partial = 0
    for r in range(bits):
        partial += int(w[r]) * int(q.astype(np.int64) @ planes[r])
        lo, hi = partial + m_min[r], partial + m_max[r]
        assert lo <= exact <= hi, (
            f"round {r}: [{lo}, {hi}] does not contain {exact}")
    assert partial == exact  # all planes consumed -> exact score


@given(int_vectors())
@_settings
def test_bitplane_roundtrip(qk):
    _, k = qk
    planes = qlib.to_bitplanes(jnp.asarray(k), 12)
    back = qlib.from_bitplanes(planes)
    np.testing.assert_array_equal(np.asarray(back), k)


@given(st.integers(0, 2**32 - 1), st.floats(0.1, 0.9))
@_settings
def test_mode_always_survives(seed, alpha):
    """The argmax-score token can never be pruned by LATS (its upper bound
    is >= its own lower bound > eta)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (4, 16)) * 3
    k = jax.random.normal(ks[1], (32, 16)) * 3
    v = jax.random.normal(ks[2], (32, 8))
    res = besf_attention(q, k, v, cfg=BitStopperConfig(alpha=float(alpha)))
    scores = np.asarray(res.scores)
    surv = np.asarray(res.stats.survivors)
    # scores of pruned = NEG_INF so argmax over scores is a survivor.
    for i in range(scores.shape[0]):
        assert surv[i, scores[i].argmax()], f"query {i} lost its mode"


@given(st.integers(0, 2**32 - 1), st.sampled_from([0.3, 0.6]))
@_settings
def test_block_variant_is_conservative(seed, alpha):
    """The streaming prefix-max block variant keeps a SUPERSET of the
    faithful global-max reference's survivors (quality >= paper)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (8, 16)) * 4
    k = jax.random.normal(ks[1], (32, 16)) * 4
    v = jax.random.normal(ks[2], (32, 8))
    cfg = BitStopperConfig(alpha=alpha)
    ref = besf_attention(q, k, v, cfg=cfg)
    blk = block_bitstopper_attention(q, k, v, cfg=cfg, block_q=4, block_k=8)
    ref_surv = np.asarray(ref.stats.survivors)
    blk_surv = np.asarray(blk.stats.survivors)
    assert (blk_surv | ~ref_surv).all(), "block variant pruned a token the \
faithful reference kept"


@given(st.integers(0, 2**32 - 1))
@_settings
def test_survivor_scores_are_exact(seed):
    """Stage fusion: a surviving token's logit equals the full-precision
    INT12 dot product (prediction work == execution work)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (4, 8)) * 2
    k = jax.random.normal(ks[1], (16, 8)) * 2
    v = jax.random.normal(ks[2], (16, 4))
    res = besf_attention(q, k, v, cfg=BitStopperConfig(alpha=0.5))
    q_int, qp = qlib.quantize(q, 12)
    k_int, kp = qlib.quantize(k, 12)
    exact = np.asarray(q_int @ k_int.T, np.float64) * float(
        qp.scale * kp.scale / 8 ** 0.5)
    scores = np.asarray(res.scores)
    surv = np.asarray(res.stats.survivors)
    np.testing.assert_allclose(scores[surv], exact[surv], rtol=1e-5)


@given(st.integers(0, 2**32 - 1), st.integers(1, 4))
@_settings
def test_chunked_loss_matches_direct(seed, chunks):
    """chunked_lm_loss == naive full-logits loss."""
    from repro.train.train_step import chunked_lm_loss, lm_loss
    from repro.models import layers as L
    key = jax.random.PRNGKey(seed)
    B, S, D, V = 2, 8 * chunks, 16, 32
    h = jax.random.normal(key, (B, S, D))
    table = jax.random.normal(jax.random.PRNGKey(seed + 1), (V, D))
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 2), (B, S), 0, V)
    params = {"embed": {"table": table}}

    class Cfg:
        tie_embeddings = True
    got = chunked_lm_loss(h, params, tokens, Cfg, chunk=8)
    logits = L.unembed(params["embed"], h)
    want = lm_loss(logits, tokens)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


@given(st.integers(0, 2**32 - 1))
@_settings
def test_int8_error_feedback_reduces_bias(seed):
    """Compression error with feedback stays bounded and unbiased-ish:
    sum of delivered grads ~ sum of true grads."""
    from repro.train.train_step import _compress_int8
    rng = np.random.default_rng(seed)
    g_true = rng.normal(size=(64,)).astype(np.float32)
    err = jnp.zeros((64,))
    delivered = np.zeros((64,))
    for _ in range(8):
        q, scale, err = _compress_int8(jnp.asarray(g_true), err)
        delivered += np.asarray(q, np.float32) * float(scale)
    np.testing.assert_allclose(delivered / 8, g_true, atol=2e-2)


@given(st.integers(0, 2**32 - 1), st.integers(1, 3))
@_settings
def test_pack_unpack_seq(seed, bits_pow):
    rng = np.random.default_rng(seed)
    S, d = 16, 8
    bits = 4 * bits_pow
    vals = rng.integers(-(2 ** (bits - 1)), 2 ** (bits - 1), (S, d))
    planes = qlib.to_bitplanes(jnp.asarray(vals, jnp.int32), bits)
    packed = qlib.pack_planes_seq(planes)
    assert packed.shape == (bits, S // 8, d)
    unpacked = qlib.unpack_planes_seq(packed)
    np.testing.assert_array_equal(np.asarray(unpacked), np.asarray(planes))
