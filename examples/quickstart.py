"""Quickstart: BitStopper attention in five minutes.

Runs the paper's three mechanisms on real tensors and prints what each one
does — faithful per-token BESF, the TPU block-granular variant, and the
fused Pallas kernel (interpret mode on CPU) — then drops it into a full
transformer.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.besf import BitStopperConfig, besf_attention
from repro.core.block_adaptation import block_bitstopper_attention
from repro.kernels.bitstopper_qk import bitstopper_attention_kernel
from repro.kernels import ref as ref_lib


def main():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    S, d = 256, 64
    # A spiky attention distribution (what LATS exploits).
    u = jax.random.normal(ks[0], (d,))
    u = u / jnp.linalg.norm(u)
    q = 6.0 * u[None, :] + 0.3 * jax.random.normal(ks[1], (64, d))
    k = jnp.concatenate([
        6.0 * u[None, :] + 0.3 * jax.random.normal(ks[2], (32, d)),
        0.3 * jax.random.normal(ks[3], (S - 32, d)),
    ])
    v = jax.random.normal(jax.random.PRNGKey(9), (S, d))
    cfg = BitStopperConfig(alpha=0.5)

    print("=== 1. Faithful per-token BESF (paper Fig. 5) ===")
    res = besf_attention(q, k, v, cfg=cfg)
    pf = np.asarray(res.stats.planes_fetched)
    sv = np.asarray(res.stats.survivors)
    print(f"  mean bit planes fetched per (q,k) pair: {pf.mean():.2f} / 12")
    print(f"  survivors (exact-score tokens):          {sv.mean()*100:.1f}%")

    print("=== 2. TPU block-granular adaptation (kernel oracle) ===")
    bres = block_bitstopper_attention(q, k, v, cfg=cfg, block_q=32, block_k=32)
    r = np.asarray(bres.stats.rounds_per_block)
    print(f"  mean plane-DMAs per (q-tile, kv-block):  {r.mean():.2f} / 12")
    print(f"  kv-blocks whose V was fetched:           "
          f"{np.asarray(bres.stats.block_alive).mean()*100:.1f}%")

    print("=== 3. Fused Pallas kernel (interpret=True on CPU) ===")
    kout = bitstopper_attention_kernel(q, k, v, cfg=cfg, block_q=32,
                                       block_k=32)
    np.testing.assert_allclose(kout.out, bres.out, atol=2e-5, rtol=2e-5)
    print("  kernel output == block oracle: OK")
    dense = ref_lib.flash_attention(q, k, v)
    err = float(jnp.mean(jnp.abs(kout.out - dense))
                / jnp.mean(jnp.abs(dense)))
    print(f"  relative error vs exact dense attention: {err*100:.2f}%")

    print("=== 4. Inside a transformer (reduced stablelm-1.6b) ===")
    from repro.configs import reduced_config
    from repro.models import transformer as T
    mcfg = reduced_config("stablelm-1.6b").replace(
        attn_impl="bitstopper_xla", bitstopper=cfg)
    params = T.init_model(jax.random.PRNGKey(1), mcfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, mcfg.vocab)
    logits, _, _ = T.forward(params, tokens, mcfg)
    print(f"  logits {logits.shape}, finite: {bool(jnp.isfinite(logits).all())}")


if __name__ == "__main__":
    main()
