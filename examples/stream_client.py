"""Minimal asyncio client for the async serving front door.

Start the server in one shell:

    PYTHONPATH=src python -m repro.launch.serve_async --impl xla

then stream two requests concurrently from another:

    PYTHONPATH=src python examples/stream_client.py

The protocol is newline-delimited JSON (``launch/serve_async.py``
docstring): send ``{"prompt": [ints], "max_new_tokens": N, "slo": ...,
"deadline_s": ...}``, read back an ack ``{"rid": r}``, one ``{"rid": r,
"token": t}`` line per token *as the engine commits it* (not at the
end), and a final ``{"rid": r, "done": true, "reason": ...}``.  The
``deadline_s`` is a wall-clock budget the server maps onto engine-tick
deadlines via its SLA mapper; a request that runs out is truncated
(``"reason": "deadline"``) rather than dropped, and the tokens it did
stream are a prefix of the undisturbed stream.
"""

import argparse
import asyncio
import json

import numpy as np


async def request(host, port, prompt, max_new_tokens, slo, deadline_s,
                  tag):
    reader, writer = await asyncio.open_connection(host, port)
    msg = {"prompt": [int(t) for t in prompt],
           "max_new_tokens": max_new_tokens, "slo": slo}
    if deadline_s is not None:
        msg["deadline_s"] = deadline_s
    writer.write(json.dumps(msg).encode() + b"\n")
    await writer.drain()
    writer.write_eof()

    rid, toks = None, []
    async for line in reader:
        event = json.loads(line)
        if "error" in event:
            print(f"[{tag}] rejected: {event['error']}")
            break
        if "token" in event:
            toks.append(event["token"])
            print(f"[{tag}] rid {event['rid']} token #{len(toks)}: "
                  f"{event['token']}")
        elif event.get("done"):
            print(f"[{tag}] rid {event['rid']} {event['reason']}: "
                  f"{event['tokens']}")
            assert event["tokens"] == toks    # stream == final transcript
            break
        else:
            rid = event["rid"]
            print(f"[{tag}] accepted as rid {rid}")
    writer.close()
    return toks


async def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8763)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="wall-clock deadline for the second request")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    # Two concurrent streams: a strict request and a best-effort one
    # carrying a wall-clock deadline.  Their tokens interleave as the
    # engine's continuous batching serves both slots each tick.
    await asyncio.gather(
        request(args.host, args.port,
                rng.integers(0, args.vocab, 12), args.new_tokens,
                "strict", None, "A"),
        request(args.host, args.port,
                rng.integers(0, args.vocab, 7), args.new_tokens,
                "besteffort", args.deadline_s, "B"),
    )


if __name__ == "__main__":
    asyncio.run(main())
