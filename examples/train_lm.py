"""End-to-end driver: train a ~12M-param LM for a few hundred steps with
checkpoint/restart, then evaluate dense vs BitStopper attention quality.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.besf import BitStopperConfig
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import transformer as T
from repro.models.config import ModelConfig, uniform_segments
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig

LM = ModelConfig(
    name="example-12m", family="dense", d_model=384, vocab=1024,
    segments=uniform_segments(6), n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1024, tie_embeddings=True,
)


def eval_loss(params, cfg, batches):
    from repro.train.train_step import loss_fn, TrainConfig as TC
    total = 0.0
    for b in batches:
        total += float(loss_fn(params, jnp.asarray(b), cfg, TC()))
    return total / len(batches)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_lm")
    args = ap.parse_args()

    data = DataConfig(vocab=LM.vocab, seq_len=256, global_batch=16, seed=1)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3),
                       total_steps=args.steps,
                       warmup_steps=args.steps // 10)
    run = TrainerConfig(steps=args.steps, ckpt_every=100,
                        ckpt_dir=args.ckpt_dir, log_every=25)
    trainer = Trainer(LM, tcfg, run, data_cfg=data)
    state = trainer.train()
    params = state["params"]

    print("\n=== quality: dense vs BitStopper attention at α=0.6 ===")
    ds = SyntheticLMDataset(data)
    eval_batches = [ds.batch_at(10_000 + i) for i in range(4)]
    dense = eval_loss(params, LM, eval_batches)
    sparse = eval_loss(
        params,
        LM.replace(attn_impl="bitstopper_xla",
                   bitstopper=BitStopperConfig(alpha=0.6)),
        eval_batches)
    print(f"  dense INT-free loss:       {dense:.4f}")
    print(f"  bitstopper (alpha=0.6):    {sparse:.4f}")
    print(f"  delta:                     {sparse - dense:+.4f} "
          f"(paper: ~+0.1 PPL-equivalent budget)")


if __name__ == "__main__":
    main()
