"""Paged continuous-batching serving with BitStopper sparse attention (the
deployment shape of the paper's accelerator): a mixed-length request trace
flows through the admission queue, prompts prefill in fixed-size chunks
interleaved with in-flight decode, every decode step runs the single-query
BESF fast path, and the KV cache is a refcounted block pool — requests
sharing a prompt prefix (here: a common system prompt) map the same
physical blocks and skip recomputing them.

Paged-cache knobs on ``ServeConfig`` (also exposed as ``--page-size`` /
``--pool-blocks`` / ``--prefill-chunk`` on ``python -m repro.launch.serve``):

* ``page_size``      — tokens per KV block (block-granular allocation)
* ``pool_blocks``    — physical blocks in the pool; admission is bounded
                       by free blocks, not by a per-slot ``max_len``
* ``prefill_chunk``  — prompt tokens per scheduler tick (bounds decode
                       latency jitter from long prompts)
* ``prefix_sharing`` — publish full prompt blocks for copy-on-write reuse

    PYTHONPATH=src python examples/serve_sparse.py
"""

import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.serving import PagedEngine, Request, ServeConfig


def main():
    cfg = reduced_config("granite-20b").replace(   # MQA: biggest K-traffic win
        attn_impl="bitstopper_xla",
        bitstopper=BitStopperConfig(alpha=0.5),
    )
    # Brief training first: a random-weight model attends uniformly, and
    # LATS (correctly) refuses to prune a flat distribution — sparsity only
    # exists once attention has learned to concentrate.
    from repro.data import DataConfig
    from repro.train.train_step import TrainConfig
    from repro.train.trainer import Trainer, TrainerConfig
    tr = Trainer(cfg.replace(attn_impl="xla"),
                 TrainConfig(total_steps=120, warmup_steps=12),
                 TrainerConfig(steps=120, ckpt_every=10**9,
                               ckpt_dir="/tmp/serve_sparse_ckpt",
                               log_every=40),
                 data_cfg=DataConfig(vocab=cfg.vocab, seq_len=128,
                                     global_batch=8, seed=3))
    state = tr.train()
    params = state["params"]
    engine = PagedEngine(
        cfg, params, ServeConfig(max_len=96, max_slots=2, prefill_bucket=8,
                                 page_size=8, prefill_chunk=16))

    # Mixed-length trace with more requests than slots and a common system
    # prompt: the queue drains as slots free up, and the shared prefix is
    # resident in the block pool exactly once.
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(0, cfg.vocab, 16, dtype=np.int32)
    requests = [
        Request(prompt=np.concatenate(
                    [system_prompt,
                     rng.integers(0, cfg.vocab, L, dtype=np.int32)]),
                max_new_tokens=16)
        for L in (8, 32, 17, 32)
    ]
    t0 = time.monotonic()
    engine.generate(requests, seed=0)
    dt = time.monotonic() - t0
    n = sum(len(r.generated) for r in requests)
    print(f"served {len(requests)} requests / {n} tokens in {dt:.2f}s "
          f"({engine.counters})")
    print(f"kv pool: peak {engine.pool.peak_live_blocks} live blocks = "
          f"{engine.kv_bytes_resident() / 1024:.1f} KiB resident "
          f"(contiguous slots would reserve "
          f"{engine.kv_bytes_contiguous_equiv() / 1024:.1f} KiB); "
          f"prefix hits {engine.counters['prefix_hit_tokens']} tokens")
    for r in requests:
        print(f"  req{r.rid} (len {len(r.prompt)}): {r.generated}")

    rep = engine.sparsity_report([r.prompt for r in requests])
    print("\nmeasured BitStopper traffic (layer 0, per served request):")
    for pr in rep["per_request"]:
        print(f"  len={pr['prompt_len']:3d}  "
              f"bit planes fetched: {pr['plane_fraction']*100:5.1f}% of dense  "
              f"kv-blocks V-fetched: {pr['block_alive_fraction']*100:5.1f}%  "
              f"survivors: {pr['survivor_fraction']*100:5.1f}%")
    print(f"aggregate: planes {rep['plane_fraction']*100:.1f}%, "
          f"V-blocks {rep['block_alive_fraction']*100:.1f}%, "
          f"survivors {rep['survivor_fraction']*100:.1f}%")


if __name__ == "__main__":
    main()
