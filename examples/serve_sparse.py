"""Continuous-batching serving with BitStopper sparse attention (the
deployment shape of the paper's accelerator): a mixed-length request trace
flows through the admission queue, prefill interleaves with in-flight
decode, and every decode step runs the single-query BESF fast path.

    PYTHONPATH=src python examples/serve_sparse.py
"""

import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.serving import ContinuousBatchingEngine, Request, ServeConfig


def main():
    cfg = reduced_config("granite-20b").replace(   # MQA: biggest K-traffic win
        attn_impl="bitstopper_xla",
        bitstopper=BitStopperConfig(alpha=0.5),
    )
    # Brief training first: a random-weight model attends uniformly, and
    # LATS (correctly) refuses to prune a flat distribution — sparsity only
    # exists once attention has learned to concentrate.
    from repro.data import DataConfig
    from repro.train.train_step import TrainConfig
    from repro.train.trainer import Trainer, TrainerConfig
    tr = Trainer(cfg.replace(attn_impl="xla"),
                 TrainConfig(total_steps=120, warmup_steps=12),
                 TrainerConfig(steps=120, ckpt_every=10**9,
                               ckpt_dir="/tmp/serve_sparse_ckpt",
                               log_every=40),
                 data_cfg=DataConfig(vocab=cfg.vocab, seq_len=128,
                                     global_batch=8, seed=3))
    state = tr.train()
    params = state["params"]
    engine = ContinuousBatchingEngine(
        cfg, params, ServeConfig(max_len=96, max_slots=2, prefill_bucket=8))

    # Mixed-length trace with more requests than slots: the queue drains
    # as slots free up — no length bucketing, no re-padding.
    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, L, dtype=np.int32),
                max_new_tokens=16)
        for L in (24, 48, 33, 48)
    ]
    t0 = time.monotonic()
    engine.generate(requests, seed=0)
    dt = time.monotonic() - t0
    n = sum(len(r.generated) for r in requests)
    print(f"served {len(requests)} requests / {n} tokens in {dt:.2f}s "
          f"({engine.counters})")
    for r in requests:
        print(f"  req{r.rid} (len {len(r.prompt)}): {r.generated}")

    rep = engine.sparsity_report([r.prompt for r in requests])
    print("\nmeasured BitStopper traffic (layer 0, per served request):")
    for pr in rep["per_request"]:
        print(f"  len={pr['prompt_len']:3d}  "
              f"bit planes fetched: {pr['plane_fraction']*100:5.1f}% of dense  "
              f"kv-blocks V-fetched: {pr['block_alive_fraction']*100:5.1f}%  "
              f"survivors: {pr['survivor_fraction']*100:5.1f}%")
    print(f"aggregate: planes {rep['plane_fraction']*100:.1f}%, "
          f"V-blocks {rep['block_alive_fraction']*100:.1f}%, "
          f"survivors {rep['survivor_fraction']*100:.1f}%")


if __name__ == "__main__":
    main()
