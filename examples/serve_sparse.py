"""Batched serving with BitStopper sparse attention (the deployment shape
of the paper's accelerator): prefill a batch of requests, decode with the
predictor-free sparse score path, report measured traffic reduction.

    PYTHONPATH=src python examples/serve_sparse.py
"""

import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.models import transformer as T
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


def main():
    cfg = reduced_config("granite-20b").replace(   # MQA: biggest K-traffic win
        attn_impl="bitstopper_xla",
        bitstopper=BitStopperConfig(alpha=0.5),
    )
    # Brief training first: a random-weight model attends uniformly, and
    # LATS (correctly) refuses to prune a flat distribution — sparsity only
    # exists once attention has learned to concentrate.
    from repro.data import DataConfig
    from repro.train.train_step import TrainConfig
    from repro.train.trainer import Trainer, TrainerConfig
    tr = Trainer(cfg.replace(attn_impl="xla"),
                 TrainConfig(total_steps=120, warmup_steps=12),
                 TrainerConfig(steps=120, ckpt_every=10**9,
                               ckpt_dir="/tmp/serve_sparse_ckpt",
                               log_every=40),
                 data_cfg=DataConfig(vocab=cfg.vocab, seq_len=128,
                                     global_batch=8, seed=3))
    state = tr.train()
    params = state["params"]
    engine = ServingEngine(cfg, params, ServeConfig(max_len=96))

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, 48, dtype=np.int32),
                max_new_tokens=16)
        for _ in range(4)
    ]
    t0 = time.monotonic()
    engine.generate(requests)
    dt = time.monotonic() - t0
    n = sum(len(r.generated) for r in requests)
    print(f"served {len(requests)} requests / {n} tokens in {dt:.2f}s")
    for i, r in enumerate(requests):
        print(f"  req{i}: {r.generated}")

    rep = engine.sparsity_report(np.stack([r.prompt for r in requests]))
    print("\nmeasured BitStopper traffic on this batch (layer 0):")
    print(f"  bit planes fetched:   {rep['plane_fraction']*100:.1f}% of dense")
    print(f"  kv-blocks V-fetched:  {rep['block_alive_fraction']*100:.1f}%")
    print(f"  surviving (q,k) pairs:{rep['survivor_fraction']*100:.1f}%")


if __name__ == "__main__":
    main()
