"""Paged continuous-batching serving with BitStopper sparse attention (the
deployment shape of the paper's accelerator): a mixed-length request trace
flows through the admission queue, prompts prefill in fixed-size chunks
interleaved with in-flight decode, every decode step runs the single-query
BESF fast path, and the KV cache is a refcounted block pool — requests
sharing a prompt prefix (here: a common system prompt) map the same
physical blocks and skip recomputing them.

Paged-cache knobs on ``ServeConfig`` (also exposed as ``--page-size`` /
``--pool-blocks`` / ``--prefill-chunk`` on ``python -m repro.launch.serve``):

* ``page_size``      — tokens per KV block (block-granular allocation)
* ``pool_blocks``    — physical blocks in the pool; admission is bounded
                       by free blocks, not by a per-slot ``max_len``
* ``prefill_chunk``  — prompt tokens per scheduler tick (bounds decode
                       latency jitter from long prompts)
* ``prefix_sharing`` — publish full prompt blocks for copy-on-write reuse

    PYTHONPATH=src python examples/serve_sparse.py
"""

import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.serving import PagedEngine, Request, ServeConfig


def main():
    cfg = reduced_config("granite-20b").replace(   # MQA: biggest K-traffic win
        attn_impl="bitstopper_xla",
        bitstopper=BitStopperConfig(alpha=0.5),
    )
    # Brief training first: a random-weight model attends uniformly, and
    # LATS (correctly) refuses to prune a flat distribution — sparsity only
    # exists once attention has learned to concentrate.
    from repro.data import DataConfig
    from repro.train.train_step import TrainConfig
    from repro.train.trainer import Trainer, TrainerConfig
    tr = Trainer(cfg.replace(attn_impl="xla"),
                 TrainConfig(total_steps=120, warmup_steps=12),
                 TrainerConfig(steps=120, ckpt_every=10**9,
                               ckpt_dir="/tmp/serve_sparse_ckpt",
                               log_every=40),
                 data_cfg=DataConfig(vocab=cfg.vocab, seq_len=128,
                                     global_batch=8, seed=3))
    state = tr.train()
    params = state["params"]
    engine = PagedEngine(
        cfg, params, ServeConfig(max_len=96, max_slots=2, prefill_bucket=8,
                                 page_size=8, prefill_chunk=16))

    # Mixed-length trace with more requests than slots and a common system
    # prompt: the queue drains as slots free up, and the shared prefix is
    # resident in the block pool exactly once.
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(0, cfg.vocab, 16, dtype=np.int32)
    requests = [
        Request(prompt=np.concatenate(
                    [system_prompt,
                     rng.integers(0, cfg.vocab, L, dtype=np.int32)]),
                max_new_tokens=16)
        for L in (8, 32, 17, 32)
    ]
    t0 = time.monotonic()
    engine.generate(requests, seed=0)
    dt = time.monotonic() - t0
    n = sum(len(r.generated) for r in requests)
    print(f"served {len(requests)} requests / {n} tokens in {dt:.2f}s "
          f"({engine.counters})")
    print(f"kv pool: peak {engine.pool.peak_live_blocks} live blocks = "
          f"{engine.kv_bytes_resident() / 1024:.1f} KiB resident "
          f"(contiguous slots would reserve "
          f"{engine.kv_bytes_contiguous_equiv() / 1024:.1f} KiB); "
          f"prefix hits {engine.counters['prefix_hit_tokens']} tokens")
    for r in requests:
        print(f"  req{r.rid} (len {len(r.prompt)}): {r.generated}")

    rep = engine.sparsity_report([r.prompt for r in requests])
    print("\nmeasured BitStopper traffic (layer 0, per served request):")
    for pr in rep["per_request"]:
        print(f"  len={pr['prompt_len']:3d}  "
              f"bit planes fetched: {pr['plane_fraction']*100:5.1f}% of dense  "
              f"kv-blocks V-fetched: {pr['block_alive_fraction']*100:5.1f}%  "
              f"survivors: {pr['survivor_fraction']*100:5.1f}%")
    print(f"aggregate: planes {rep['plane_fraction']*100:.1f}%, "
          f"V-blocks {rep['block_alive_fraction']*100:.1f}%, "
          f"survivors {rep['survivor_fraction']*100:.1f}%")

    # ---- speculative decoding (--speculative ngram on the launcher) ----
    # The n-gram prompt-lookup drafter proposes continuations of repeated
    # patterns in the request's own context; one Sq=k+1 BitStopper verify
    # forward scores the whole draft block (each query bit-identical to
    # the Sq=1 decode at its position) and rejected tails roll back as a
    # block-table operation.  Lossless: same tokens, fewer forwards — the
    # win scales with how repetitive the text is, so the demo trace below
    # repeats a motif.
    motif = rng.integers(0, cfg.vocab, 6, dtype=np.int32)
    rep_prompt = np.tile(motif, 6)
    spec_reqs = [Request(prompt=rep_prompt.copy(), max_new_tokens=18)
                 for _ in range(2)]
    spec_engine = PagedEngine(
        cfg, params, ServeConfig(max_len=96, max_slots=2, prefill_bucket=8,
                                 page_size=8, prefill_chunk=16,
                                 speculative="ngram", draft_k=4))
    plain_reqs = [Request(prompt=rep_prompt.copy(), max_new_tokens=18)
                  for _ in range(2)]
    plain_engine = PagedEngine(
        cfg, params, ServeConfig(max_len=96, max_slots=2, prefill_bucket=8,
                                 page_size=8, prefill_chunk=16))
    plain_engine.generate(plain_reqs, seed=0)
    spec_engine.generate(spec_reqs, seed=0)
    c, pc = spec_engine.counters, plain_engine.counters
    acc = c["spec_accepted"] / max(1, c["spec_proposed"])
    assert [r.generated for r in spec_reqs] == \
        [r.generated for r in plain_reqs], "speculative must be lossless"
    print(f"\nspeculative n-gram serving (repetitive trace): "
          f"{c['decode_tokens']} tokens in {c['decode_steps']} ticks "
          f"({c['decode_tokens']/max(1,c['decode_steps']):.2f} tokens/tick "
          f"vs {pc['decode_tokens']/max(1,pc['decode_steps']):.2f} plain), "
          f"acceptance {acc:.0%}, "
          f"{c['spec_bailouts']} scale-growth bailouts "
          f"— tokens identical to plain decode")


if __name__ == "__main__":
    main()
