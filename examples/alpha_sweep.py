"""Reproduce the paper's Fig. 13(a) trade-off on live tensors: sweep the
pruning parameter α and print quality vs complexity-reduction (the curve
whose plateau below α≈0.6 motivates the paper's default).

    PYTHONPATH=src python examples/alpha_sweep.py
"""

import numpy as np

from benchmarks.fig12_13 import run_fig13a


def main():
    rows = run_fig13a()
    print(f"{'alpha':>6} {'mass kept':>10} {'out err':>9} "
          f"{'compute cut':>12} {'memory cut':>11} {'kept':>6}")
    for r in rows:
        print(f"{r['alpha']:>6.1f} {r['quality_mass']*100:>9.2f}% "
              f"{r['rel_output_err']*100:>8.2f}% "
              f"{r['complexity_reduction']*100:>11.1f}% "
              f"{r['mem_reduction']*100:>10.1f}% "
              f"{r['kept_frac']*100:>5.1f}%")
    # the paper's observation: below ~0.6 quality falls faster than
    # complexity improves
    errs = [r["rel_output_err"] for r in rows]
    cuts = [r["complexity_reduction"] for r in rows]
    print("\npaper Fig. 13(a) shape check: aggressive alphas should add "
          "error faster than they add savings")
    print(f"  err(0.2)/err(0.8)   = {errs[0] / max(errs[-1], 1e-9):.1f}x")
    print(f"  cut(0.2)-cut(0.8)   = {(cuts[0] - cuts[-1]) * 100:.1f} pts")


if __name__ == "__main__":
    main()
